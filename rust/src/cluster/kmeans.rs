//! K-means device clustering (paper §4.2): k-means++ seeding + Lloyd
//! iterations, parallel over points. This is the server-side clustering
//! engine for the proposed encoder summaries; `runtime::KmeansHlo` offers
//! the same Lloyd step through the AOT Pallas-kernel artifact.
//!
//! Two assignment kernels share the loop: the naive full scan ([`assign`])
//! and the bound-pruned path ([`assign_pruned`]) built on the
//! `‖x‖² − 2x·c + ‖c‖²` decomposition with cached norms plus Hamerly-style
//! triangle-inequality bounds. Pruning only ever skips a centroid it can
//! *prove* cannot win; every surviving candidate is decided by the exact
//! [`sqdist`], so both kernels return bitwise-identical assignments and
//! inertia (property-tested here and in `rust/tests/proptests.rs`).

use crate::cluster::Pruning;
use crate::util::mat::{
    dot8, dot8_i8, quant_sqnorm, row_sqnorms, sqdist, sqdist_quant, sum_i8, Mat, QuantMat,
};
use crate::util::parallel::{default_threads, map_chunks};
use crate::util::rng::Rng;

/// K-means configuration.
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Relative inertia improvement below which we stop.
    pub tol: f64,
    pub seed: u64,
    pub threads: usize,
    /// Assignment kernel selection (bitwise-identical either way).
    pub pruning: Pruning,
}

impl KmeansConfig {
    pub fn new(k: usize) -> Self {
        KmeansConfig {
            k,
            max_iters: 50,
            tol: 1e-4,
            seed: 0,
            threads: default_threads(),
            pruning: Pruning::default(),
        }
    }
}

/// Distance-computation accounting for one assignment pass (or a whole fit,
/// via [`AssignStats::merge`]). `runtime_hotpath` reports these in
/// `BENCH_kernels.json`.
#[derive(Debug, Clone, Copy, Default)]
pub struct AssignStats {
    /// point × centroid pairs considered.
    pub pairs: u64,
    /// Exact `sqdist` evaluations performed.
    pub exact: u64,
    /// Decomposed-screen dot products (the no-hint first pass).
    pub screened: u64,
}

impl AssignStats {
    pub fn merge(&mut self, o: &AssignStats) {
        self.pairs += o.pairs;
        self.exact += o.exact;
        self.screened += o.screened;
    }

    /// Fraction of pairs that needed no exact distance evaluation — the
    /// "distance-computation skip rate" the benches report. `exact ≤ pairs`
    /// always (at most one evaluation per pair), so this lies in [0, 1].
    /// Screening dot products are cheaper than `sqdist` and are accounted
    /// separately in [`AssignStats::screened`] (quoted alongside the skip
    /// rate in `BENCH_kernels.json`), not folded into this rate.
    pub fn skip_rate(&self) -> f64 {
        if self.pairs == 0 {
            return 0.0;
        }
        1.0 - self.exact as f64 / self.pairs as f64
    }
}

/// Result of a K-means fit.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub centroids: Mat,
    pub assignments: Vec<usize>,
    pub inertia: f64,
    pub iters: usize,
    /// Aggregate distance-computation accounting across all Lloyd rounds
    /// (all-exact when the naive kernel ran).
    pub stats: AssignStats,
}

/// k-means++ initialization (Arthur & Vassilvitskii 2007).
pub fn kmeanspp_init(points: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = points.rows();
    assert!(n >= k, "kmeans++: n={n} < k={k}");
    let mut centroids = Mat::zeros(0, points.cols());
    let first = rng.below(n as u64) as usize;
    centroids.push_row(points.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| points.sqdist_row(i, centroids.row(0))).collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all points identical to chosen centroids: pick uniformly
            rng.below(n as u64) as usize
        } else {
            rng.weighted_index(&d2)
        };
        centroids.push_row(points.row(next));
        let c = centroids.rows() - 1;
        for i in 0..n {
            let d = points.sqdist_row(i, centroids.row(c));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Assign each point to its nearest centroid; returns (assignments, inertia).
///
/// The inertia is reduced serially in point order from per-point values, NOT
/// from per-chunk partial sums: f64 addition is non-associative, so chunked
/// partials would make the total (and anything derived from it, like Lloyd's
/// convergence round) depend on the thread count. This keeps the whole
/// clustering pipeline bitwise thread-count invariant.
pub fn assign(points: &Mat, centroids: &Mat, threads: usize) -> (Vec<usize>, f64) {
    let n = points.rows();
    let k = centroids.rows();
    let chunks = map_chunks(n, threads, |lo, hi| {
        let mut a = Vec::with_capacity(hi - lo);
        let mut d2 = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            let row = points.row(i);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sqdist(row, centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            a.push(best);
            d2.push(best_d);
        }
        (a, d2)
    });
    let mut assignments = Vec::with_capacity(n);
    let mut inertia = 0.0f64;
    for (a, d2) in chunks {
        assignments.extend(a);
        for d in d2 {
            inertia += d;
        }
    }
    (assignments, inertia)
}

/// Relative safety factor for the pruning inequalities: covers the rounding
/// of the lane-accumulated `sqdist`/`dot8` values (error grows with the
/// dimension) so a bound never skips a centroid the exact comparison could
/// still pick. Costs a negligible amount of skip rate on real data.
#[inline]
pub(crate) fn prune_margin(dims: usize) -> f64 {
    1.0 + 1e-3 + 4.0 * dims as f64 * (f32::EPSILON as f64)
}

/// Bound-pruned nearest-centroid assignment — **bitwise identical** to
/// [`assign`] (same assignments, same inertia bits) but skipping most exact
/// distance computations:
///
/// 1. A starting candidate per point: the caller's `hint` (the previous
///    Lloyd round's assignment) when present, else the lexicographic argmin
///    of the `‖x‖² − 2x·c + ‖c‖²` decomposition over cached row norms — one
///    cheap screening dot per centroid instead of a full `sqdist`.
/// 2. One exact `sqdist` to the candidate gives the upper bound `ub²`.
///    Hamerly fast path: if the nearest *other* centroid satisfies
///    `‖c_b − c_j‖² > 4·ub²` for all j (via the cached min inter-centroid
///    distance), every other centroid is provably farther and the point is
///    done after a single exact evaluation.
/// 3. Otherwise each remaining centroid is tested against the triangle
///    bound `‖c_best − c_j‖² > 4·best²` (with [`prune_margin`] slack) and
///    skipped only when it provably cannot win; survivors are decided by
///    the exact [`sqdist`] with [`assign`]'s tie-break (lowest index).
///
/// The inertia is reduced serially in point order from per-point exact
/// values, like [`assign`], so the whole result — and therefore Lloyd's
/// convergence trajectory — is bitwise thread-count invariant.
pub fn assign_pruned(
    points: &Mat,
    centroids: &Mat,
    threads: usize,
    hints: Option<&[usize]>,
) -> (Vec<usize>, f64, AssignStats) {
    let n = points.rows();
    let k = centroids.rows();
    let d = points.cols();
    let margin = prune_margin(d);
    // Exact inter-centroid distances + Hamerly's s (min over other
    // centroids): k²·d work, negligible against n·k·d for n >> k.
    let mut cc2 = vec![0.0f64; k * k];
    for a in 0..k {
        for b in (a + 1)..k {
            let v = sqdist(centroids.row(a), centroids.row(b));
            cc2[a * k + b] = v;
            cc2[b * k + a] = v;
        }
    }
    // Hamerly's s: nearest OTHER centroid per centroid. A row containing
    // any non-finite entry (overflow/NaN) gets s = ∞, which the fast
    // path's `is_finite` gate rejects: an overflowed distance carries no
    // magnitude information, so the fast path may not vouch for that row —
    // a finite min over only the well-behaved entries would wrongly prune
    // the overflowed centroid itself, which can be the true nearest.
    let mut s = vec![f64::INFINITY; k];
    for a in 0..k {
        for b in 0..k {
            if a == b {
                continue;
            }
            let v = cc2[a * k + b];
            if !v.is_finite() {
                s[a] = f64::INFINITY;
                break;
            }
            if v < s[a] {
                s[a] = v;
            }
        }
    }
    let c2 = if hints.is_none() { row_sqnorms(centroids) } else { Vec::new() };

    let chunks = map_chunks(n, threads, |lo, hi| {
        let mut a_out = Vec::with_capacity(hi - lo);
        let mut d2_out = Vec::with_capacity(hi - lo);
        let mut stats = AssignStats::default();
        for i in lo..hi {
            let row = points.row(i);
            stats.pairs += k as u64;
            let b0 = match hints {
                Some(h) if h[i] < k => h[i],
                Some(_) => 0,
                None => {
                    // Decomposed screen: x² − 2x·c + c² per centroid,
                    // lexicographic argmin — a good first guess that makes
                    // the exact upper bound tight.
                    let x2 = dot8(row, row);
                    let mut best = 0usize;
                    let mut best_t = f64::INFINITY;
                    for c in 0..k {
                        let t = x2 - 2.0 * dot8(row, centroids.row(c)) + c2[c];
                        stats.screened += 1;
                        if t < best_t {
                            best_t = t;
                            best = c;
                        }
                    }
                    best
                }
            };
            // Mirror [`assign`]'s semantics exactly, including non-finite
            // data: there a NaN (or +∞) distance never wins (`d < best_d`
            // is false), so a non-finite candidate evaluation falls back to
            // naive's (0, ∞) start — and every bound below uses a strict
            // `>` against `best_d`, which disables itself at ∞.
            let d0 = sqdist(row, centroids.row(b0));
            stats.exact += 1;
            let (mut best, mut best_d) =
                if d0 < f64::INFINITY { (b0, d0) } else { (0, f64::INFINITY) };
            // Hamerly fast path: no other centroid can possibly win. The
            // bound value must be FINITE to prune: an overflowed (+∞)
            // inter-centroid distance carries no magnitude information —
            // the true gap may be far smaller than the overflowed lanes
            // suggest — so ∞ entries fall through to exact evaluation,
            // exactly like naive's.
            if k <= 1 || (s[best].is_finite() && s[best] > 4.0 * best_d * margin) {
                a_out.push(best);
                d2_out.push(best_d);
                continue;
            }
            for c in 0..k {
                if c == b0 {
                    continue;
                }
                // Triangle bound: ‖c_best − c‖ ≥ 2·‖x − c_best‖ proves
                // ‖x − c‖ ≥ ‖x − c_best‖, strictly with the margin (finite
                // entries only — see the fast-path note).
                let cc = cc2[best * k + c];
                if cc.is_finite() && cc > 4.0 * best_d * margin {
                    continue;
                }
                let dd = sqdist(row, centroids.row(c));
                stats.exact += 1;
                if dd < best_d || (dd == best_d && c < best) {
                    best_d = dd;
                    best = c;
                }
            }
            a_out.push(best);
            d2_out.push(best_d);
        }
        (a_out, d2_out, stats)
    });
    let mut assignments = Vec::with_capacity(n);
    let mut inertia = 0.0f64;
    let mut stats = AssignStats::default();
    for (a, d2, st) in chunks {
        assignments.extend(a);
        for v in d2 {
            inertia += v;
        }
        stats.merge(&st);
    }
    (assignments, inertia, stats)
}

/// Per-row integer moments of a [`QuantMat`] — `Σq²` ([`dot8_i8`] with
/// itself), `Σq` ([`sum_i8`]), and the dequantized norm `‖x̂‖` — cached once
/// and reused across every distance the quantized kernels compute.
struct QuantMoments {
    qq: Vec<i64>,
    qsum: Vec<i64>,
    /// `‖x̂‖` (the square root of [`quant_sqnorm`]), for the norm screen.
    norm: Vec<f64>,
}

impl QuantMoments {
    fn of(m: &QuantMat) -> Self {
        let n = m.rows();
        let d = m.cols();
        let mut qq = Vec::with_capacity(n);
        let mut qsum = Vec::with_capacity(n);
        let mut norm = Vec::with_capacity(n);
        for i in 0..n {
            let row = m.row(i);
            let a = dot8_i8(row, row);
            let s = sum_i8(row);
            qq.push(a);
            qsum.push(s);
            norm.push(quant_sqnorm(m.params(i), a, s, d).max(0.0).sqrt());
        }
        QuantMoments { qq, qsum, norm }
    }
}

/// Nearest-centroid assignment over int8-quantized points with a
/// **dequant-free** screen: no f32 row is ever materialized. Centroids are
/// quantized once per call; per point the reverse-triangle norm bound
/// `(‖x̂‖ − ‖ĉ‖)² ≤ ‖x̂ − ĉ‖²` (norms from cached integer moments) skips
/// centroids that provably cannot beat the current best, and survivors are
/// decided by the exact-affine [`sqdist_quant`] (one [`dot8_i8`] each).
///
/// Unlike [`assign_pruned`] this path is *approximate* relative to the f32
/// oracle — quantization error moves points — so it is validated by
/// ARI-vs-exact (benches, Python port), not bitwise equality. It IS
/// bitwise deterministic in its own right: integer kernels are exact, the
/// f64 combining order is fixed, and the inertia reduces serially in point
/// order — so results are identical across thread counts and reruns.
pub fn assign_quantized(
    points: &QuantMat,
    centroids: &Mat,
    threads: usize,
    hints: Option<&[usize]>,
) -> (Vec<usize>, f64, AssignStats) {
    let n = points.rows();
    let k = centroids.rows();
    let qc = QuantMat::from_mat(centroids);
    let cm = QuantMoments::of(&qc);
    let pm = QuantMoments::of(points);

    let chunks = map_chunks(n, threads, |lo, hi| {
        let mut a_out = Vec::with_capacity(hi - lo);
        let mut d2_out = Vec::with_capacity(hi - lo);
        let mut stats = AssignStats::default();
        for i in lo..hi {
            let row = points.row(i);
            let (pq, ps, pn) = (pm.qq[i], pm.qsum[i], pm.norm[i]);
            let pp = points.params(i);
            stats.pairs += k as u64;
            let dist = |c: usize| {
                sqdist_quant(row, pp, pq, ps, qc.row(c), qc.params(c), cm.qq[c], cm.qsum[c])
            };
            // Warm start: the hinted centroid's exact distance makes the
            // norm bound tight from the first comparison.
            let b0 = match hints {
                Some(h) if h[i] < k => h[i],
                _ => 0,
            };
            let mut best = b0;
            let mut best_d = dist(b0);
            stats.exact += 1;
            for c in 0..k {
                if c == b0 {
                    continue;
                }
                let gap = pn - cm.norm[c];
                stats.screened += 1;
                if gap * gap > best_d {
                    continue; // provably farther than the current best
                }
                let dd = dist(c);
                stats.exact += 1;
                if dd < best_d || (dd == best_d && c < best) {
                    best_d = dd;
                    best = c;
                }
            }
            a_out.push(best);
            d2_out.push(best_d);
        }
        (a_out, d2_out, stats)
    });
    let mut assignments = Vec::with_capacity(n);
    let mut inertia = 0.0f64;
    let mut stats = AssignStats::default();
    for (a, d2, st) in chunks {
        assignments.extend(a);
        for v in d2 {
            inertia += v;
        }
        stats.merge(&st);
    }
    (assignments, inertia, stats)
}

/// k-means++ over quantized points: seeding distances are point-to-point
/// [`sqdist_quant`] (dequant-free); only the `k` chosen seed rows are
/// dequantized, into the returned f32 centroid matrix. Deterministic for a
/// given seed, like [`kmeanspp_init`].
fn kmeanspp_init_quant(points: &QuantMat, k: usize, rng: &mut Rng) -> Mat {
    let n = points.rows();
    assert!(n >= k, "kmeans++ (quant): n={n} < k={k}");
    let m = QuantMoments::of(points);
    let dist = |i: usize, j: usize| {
        sqdist_quant(
            points.row(i),
            points.params(i),
            m.qq[i],
            m.qsum[i],
            points.row(j),
            points.params(j),
            m.qq[j],
            m.qsum[j],
        )
    };
    let mut centroids = Mat::zeros(k, points.cols());
    let mut chosen = Vec::with_capacity(k);
    let first = rng.below(n as u64) as usize;
    chosen.push(first);
    points.dequantize_row_into(first, centroids.row_mut(0));
    let mut d2: Vec<f64> = (0..n).map(|i| dist(i, first)).collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.below(n as u64) as usize
        } else {
            rng.weighted_index(&d2)
        };
        chosen.push(next);
        points.dequantize_row_into(next, centroids.row_mut(c));
        for i in 0..n {
            let d = dist(i, next);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Centroid update over quantized points: each point's contribution is
/// dequantized on the fly (`scale·q + zero` per element, f64 accumulate in
/// point order) — no materialized f32 matrix. Empty clusters are re-seeded
/// to the farthest (dequantized) points, mirroring [`update_centroids`]'s
/// deterministic (distance desc, index asc) repair; quantized distances
/// are always finite so no NaN arm is needed.
fn update_centroids_quant(
    points: &QuantMat,
    assignments: &[usize],
    k: usize,
    prev: &Mat,
) -> Mat {
    let d = points.cols();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0usize; k];
    for (i, &a) in assignments.iter().enumerate() {
        counts[a] += 1;
        let p = points.params(i);
        let (s, z) = (p.scale as f64, p.zero as f64);
        let dst = &mut sums[a * d..(a + 1) * d];
        for (acc, &q) in dst.iter_mut().zip(points.row(i)) {
            *acc += s * q as f64 + z;
        }
    }
    let mut out = Mat::zeros(k, d);
    let mut empties = Vec::new();
    for c in 0..k {
        if counts[c] == 0 {
            empties.push(c);
            out.row_mut(c).copy_from_slice(prev.row(c));
        } else {
            let inv = 1.0 / counts[c] as f64;
            for (j, v) in out.row_mut(c).iter_mut().enumerate() {
                *v = (sums[c * d + j] * inv) as f32;
            }
        }
    }
    if !empties.is_empty() {
        let qo = QuantMat::from_mat(&out);
        let om = QuantMoments::of(&qo);
        let pm = QuantMoments::of(points);
        let mut far: Vec<(f64, usize)> = assignments
            .iter()
            .enumerate()
            .map(|(i, &a)| {
                let dd = sqdist_quant(
                    points.row(i),
                    points.params(i),
                    pm.qq[i],
                    pm.qsum[i],
                    qo.row(a),
                    qo.params(a),
                    om.qq[a],
                    om.qsum[a],
                );
                (dd, i)
            })
            .collect();
        let cmp =
            |a: &(f64, usize), b: &(f64, usize)| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1));
        let take = empties.len().min(far.len());
        if far.len() > take {
            far.select_nth_unstable_by(take - 1, cmp);
            far.truncate(take);
        }
        far.sort_unstable_by(cmp);
        for (e, c) in empties.into_iter().enumerate() {
            if e < far.len() {
                points.dequantize_row_into(far[e].1, out.row_mut(c));
            }
        }
    }
    out
}

/// Full Lloyd fit over int8-quantized points — the compressed-store
/// clustering path. Same shape as [`fit`] (k-means++ seeding, warm-hinted
/// assignment, tol-based convergence) but every distance goes through the
/// dequant-free quantized kernels; only seed rows, empty-cluster repairs,
/// and centroid means touch f32. Deterministic across thread counts and
/// reruns; accuracy versus the f32 oracle is held to ARI (≥ 0.95 on the
/// bench scenarios) rather than bitwise equality.
pub fn fit_quantized(points: &QuantMat, cfg: &KmeansConfig) -> KmeansResult {
    assert!(points.rows() >= cfg.k, "kmeans (quant): fewer points than clusters");
    let mut rng = Rng::new(cfg.seed);
    let mut centroids = kmeanspp_init_quant(points, cfg.k, &mut rng);
    let mut prev_inertia = f64::INFINITY;
    let mut assignments = Vec::new();
    let mut inertia = 0.0;
    let mut iters = 0;
    let mut stats = AssignStats::default();
    for it in 0..cfg.max_iters {
        let hints = if it == 0 { None } else { Some(assignments.as_slice()) };
        let (a, i, st) = assign_quantized(points, &centroids, cfg.threads, hints);
        stats.merge(&st);
        assignments = a;
        inertia = i;
        iters = it + 1;
        if prev_inertia.is_finite() && (prev_inertia - inertia) <= cfg.tol * prev_inertia.max(1e-12)
        {
            break;
        }
        prev_inertia = inertia;
        centroids = update_centroids_quant(points, &assignments, cfg.k, &centroids);
    }
    KmeansResult { centroids, assignments, inertia, iters, stats }
}

/// Recompute centroids as cluster means; empty clusters are re-seeded to the
/// point farthest from its centroid (standard Lloyd repair).
fn update_centroids(points: &Mat, assignments: &[usize], k: usize, prev: &Mat) -> Mat {
    let d = points.cols();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0usize; k];
    for (i, &a) in assignments.iter().enumerate() {
        counts[a] += 1;
        let row = points.row(i);
        let dst = &mut sums[a * d..(a + 1) * d];
        for (s, &v) in dst.iter_mut().zip(row) {
            *s += v as f64;
        }
    }
    let mut out = Mat::zeros(k, d);
    let mut empties = Vec::new();
    for c in 0..k {
        if counts[c] == 0 {
            empties.push(c);
            out.row_mut(c).copy_from_slice(prev.row(c));
        } else {
            let inv = 1.0 / counts[c] as f64;
            for (j, v) in out.row_mut(c).iter_mut().enumerate() {
                *v = (sums[c * d + j] * inv) as f32;
            }
        }
    }
    // Re-seed empty clusters to the farthest points. Ordering is (finite
    // distance desc, point index asc) with NaN distances LAST — a strict
    // total order, so the selection is deterministic, NaN-safe (the old
    // `partial_cmp().unwrap()` panicked), two empty clusters can never be
    // re-seeded to the same point (each point index appears once), and a
    // NaN-poisoned row is only chosen once every finite point is taken —
    // re-seeding a centroid to NaN would leave it permanently unwinnable.
    // `select_nth_unstable_by` finds the top-|empties| in O(n) instead of
    // sorting all n points.
    if !empties.is_empty() {
        let mut far: Vec<(f64, usize)> = assignments
            .iter()
            .enumerate()
            .map(|(i, &a)| (points.sqdist_row(i, out.row(a)), i))
            .collect();
        let cmp = |a: &(f64, usize), b: &(f64, usize)| {
            match (a.0.is_nan(), b.0.is_nan()) {
                (true, true) => a.1.cmp(&b.1),
                (true, false) => std::cmp::Ordering::Greater,
                (false, true) => std::cmp::Ordering::Less,
                (false, false) => b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)),
            }
        };
        let take = empties.len().min(far.len());
        if far.len() > take {
            far.select_nth_unstable_by(take - 1, cmp);
            far.truncate(take);
        }
        far.sort_unstable_by(cmp);
        for (e, c) in empties.into_iter().enumerate() {
            if e < far.len() {
                let idx = far[e].1;
                let row = points.row(idx).to_vec();
                out.row_mut(c).copy_from_slice(&row);
            }
        }
    }
    out
}

/// Full Lloyd fit. With pruning enabled (the `Auto` default at scale) each
/// round feeds the previous round's assignments back into
/// [`assign_pruned`] as hints: near convergence almost every point takes
/// the Hamerly fast path and the round costs ~one exact distance per point
/// instead of k. Assignments, inertia, and the convergence trajectory are
/// bitwise identical to the naive path.
pub fn fit(points: &Mat, cfg: &KmeansConfig) -> KmeansResult {
    assert!(points.rows() >= cfg.k, "kmeans: fewer points than clusters");
    let n = points.rows();
    let use_bounds = cfg.pruning.use_bounds(n, cfg.k);
    let mut rng = Rng::new(cfg.seed);
    let mut centroids = kmeanspp_init(points, cfg.k, &mut rng);
    let mut prev_inertia = f64::INFINITY;
    let mut assignments = Vec::new();
    let mut inertia = 0.0;
    let mut iters = 0;
    let mut stats = AssignStats::default();
    for it in 0..cfg.max_iters {
        let (a, i) = if use_bounds {
            let hints = if it == 0 { None } else { Some(assignments.as_slice()) };
            let (a, i, st) = assign_pruned(points, &centroids, cfg.threads, hints);
            stats.merge(&st);
            (a, i)
        } else {
            let pairs = (n * cfg.k) as u64;
            stats.merge(&AssignStats { pairs, exact: pairs, screened: 0 });
            assign(points, &centroids, cfg.threads)
        };
        assignments = a;
        inertia = i;
        iters = it + 1;
        if prev_inertia.is_finite() && (prev_inertia - inertia) <= cfg.tol * prev_inertia.max(1e-12)
        {
            break;
        }
        prev_inertia = inertia;
        centroids = update_centroids(points, &assignments, cfg.k, &centroids);
    }
    KmeansResult { centroids, assignments, inertia, iters, stats }
}

/// Root-tier merge of per-shard centroid sets (the hierarchical clustering
/// topology's approximate path): every shard centroid becomes a point
/// weighted by its member count, and a fixed number of weighted Lloyd
/// iterations runs over those ≤ S·k points. Cost is
/// Θ(iters · S·k · k · dim) — independent of fleet size, which is what
/// keeps the root tier sub-linear in N. Deterministic: points gather in
/// fixed (shard, row) order, seeds are the k heaviest centroids (input
/// order breaks ties), assignment and accumulation scan serially — the
/// same inputs always merge to the same bits. Different shard counts
/// summarize the fleet differently, so this path is approximate by nature;
/// the shard-count-*invariant* merged clustering re-fits the concatenated
/// shard matrices at the root (`coordinator::summaries`).
pub fn merge_weighted_centroids(
    sets: &[(&Mat, &[u64])],
    k: usize,
    iters: usize,
) -> (Mat, Vec<u64>) {
    let dim = sets.iter().find(|(m, _)| m.rows() > 0).map_or(0, |(m, _)| m.cols());
    let mut points = Mat::zeros(0, dim);
    let mut weights: Vec<u64> = Vec::new();
    for (m, counts) in sets {
        debug_assert_eq!(m.rows(), counts.len(), "centroid set without matching counts");
        for r in 0..m.rows() {
            // Empty local clusters carry no mass and no information.
            if counts[r] == 0 {
                continue;
            }
            points.push_row(m.row(r));
            weights.push(counts[r]);
        }
    }
    let n = points.rows();
    if k == 0 || n <= k {
        return (points, weights);
    }
    // Seed with the k heaviest shard centroids, input order breaking ties.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut centroids = Mat::zeros(0, dim);
    for &i in order.iter().take(k) {
        centroids.push_row(points.row(i));
    }
    let mut assignments = vec![0usize; n];
    for _ in 0..iters.max(1) {
        for (i, slot) in assignments.iter_mut().enumerate() {
            let row = points.row(i);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sqdist(row, centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            *slot = best;
        }
        // Count-weighted mean update, serial in point order; an emptied
        // merge cluster keeps its previous centroid.
        let mut acc = vec![0.0f64; k * dim];
        let mut mass = vec![0u64; k];
        for i in 0..n {
            let c = assignments[i];
            mass[c] += weights[i];
            let w = weights[i] as f64;
            for (j, &v) in points.row(i).iter().enumerate() {
                acc[c * dim + j] += w * v as f64;
            }
        }
        for c in 0..k {
            if mass[c] == 0 {
                continue;
            }
            let inv = 1.0 / mass[c] as f64;
            for j in 0..dim {
                centroids.row_mut(c)[j] = (acc[c * dim + j] * inv) as f32;
            }
        }
    }
    let mut mass = vec![0u64; k];
    for (i, &c) in assignments.iter().enumerate() {
        mass[c] += weights[i];
    }
    (centroids, mass)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, centers: &[(f32, f32)], spread: f32, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(0, 2);
        let mut truth = Vec::new();
        for (g, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..n_per {
                m.push_row(&[
                    cx + spread * rng.normal() as f32,
                    cy + spread * rng.normal() as f32,
                ]);
                truth.push(g);
            }
        }
        (m, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (pts, truth) = blobs(50, &[(0.0, 0.0), (10.0, 10.0), (-10.0, 10.0)], 0.3, 1);
        let res = fit(&pts, &KmeansConfig::new(3));
        let ari = crate::util::stats::adjusted_rand_index(&res.assignments, &truth);
        assert!(ari > 0.99, "ari={ari}");
        assert!(res.inertia < 150.0 * 2.0 * 0.3 * 0.3 * 4.0);
    }

    #[test]
    fn centroid_merge_recovers_structure_across_shards() {
        // Two well-separated groups, each split across two shards: the
        // root merge at k=2 must put the shard-local centroids of the same
        // group back together, with counts preserved.
        let a = Mat::from_rows(&[vec![0.0, 0.0], vec![10.0, 10.0]]);
        let b = Mat::from_rows(&[vec![0.2, -0.2], vec![10.2, 9.8]]);
        let ca = [30u64, 50];
        let cb = [10u64, 70];
        let (merged, mass) = merge_weighted_centroids(&[(&a, &ca), (&b, &cb)], 2, 5);
        assert_eq!(merged.rows(), 2);
        assert_eq!(mass.iter().sum::<u64>(), 160);
        // One merged centroid near (0,0)-ish mass 40, one near (10,10) mass 120.
        let mut got: Vec<(f32, u64)> = (0..2).map(|c| (merged.row(c)[0], mass[c])).collect();
        got.sort_by(|x, y| x.0.total_cmp(&y.0));
        assert!(got[0].0.abs() < 1.0 && got[0].1 == 40, "low centroid {got:?}");
        assert!((got[1].0 - 10.0).abs() < 1.0 && got[1].1 == 120, "high centroid {got:?}");
        // Deterministic: same inputs, same bits.
        let (again, mass2) = merge_weighted_centroids(&[(&a, &ca), (&b, &cb)], 2, 5);
        assert_eq!(merged.data(), again.data());
        assert_eq!(mass, mass2);
    }

    #[test]
    fn centroid_merge_passes_small_sets_through_and_drops_empty_clusters() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let counts = [5u64, 0];
        // One non-empty centroid against k=4: passthrough, zero-count row
        // dropped.
        let (m, mass) = merge_weighted_centroids(&[(&a, &counts)], 4, 3);
        assert_eq!(m.rows(), 1);
        assert_eq!(m.row(0), &[1.0, 2.0][..]);
        assert_eq!(mass, vec![5]);
        // No sets at all: empty merge.
        let (e, em) = merge_weighted_centroids(&[], 3, 3);
        assert_eq!(e.rows(), 0);
        assert!(em.is_empty());
    }

    #[test]
    fn inertia_nonincreasing_over_restarts_of_same_seed() {
        let (pts, _) = blobs(40, &[(0.0, 0.0), (5.0, 5.0)], 1.0, 2);
        let a = fit(&pts, &KmeansConfig::new(2));
        let b = fit(&pts, &KmeansConfig::new(2));
        assert_eq!(a.assignments, b.assignments); // deterministic
        assert!((a.inertia - b.inertia).abs() < 1e-9);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let (pts, _) = blobs(1, &[(0.0, 0.0), (1.0, 1.0), (2.0, 2.0)], 0.0, 3);
        let res = fit(&pts, &KmeansConfig::new(3));
        assert!(res.inertia < 1e-9);
        let mut a = res.assignments.clone();
        a.sort_unstable();
        a.dedup();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn identical_points_dont_crash() {
        let mut m = Mat::zeros(0, 3);
        for _ in 0..20 {
            m.push_row(&[1.0, 2.0, 3.0]);
        }
        let res = fit(&m, &KmeansConfig::new(4));
        assert!(res.inertia < 1e-9);
        assert_eq!(res.assignments.len(), 20);
    }

    #[test]
    fn single_cluster() {
        let (pts, _) = blobs(30, &[(2.0, 2.0)], 0.5, 4);
        let res = fit(&pts, &KmeansConfig::new(1));
        assert!(res.assignments.iter().all(|&a| a == 0));
        // centroid near (2,2)
        assert!((res.centroids.row(0)[0] - 2.0).abs() < 0.3);
    }

    #[test]
    fn parallel_matches_serial() {
        let (pts, _) = blobs(100, &[(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)], 1.0, 5);
        let mut cfg1 = KmeansConfig::new(3);
        cfg1.threads = 1;
        let mut cfg8 = KmeansConfig::new(3);
        cfg8.threads = 8;
        let a = fit(&pts, &cfg1);
        let b = fit(&pts, &cfg8);
        assert_eq!(a.assignments, b.assignments);
        assert!((a.inertia - b.inertia).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "fewer points")]
    fn too_few_points_panics() {
        let (pts, _) = blobs(1, &[(0.0, 0.0)], 0.0, 6);
        fit(&pts, &KmeansConfig::new(5));
    }

    /// The tentpole oracle: the bound-pruned kernel equals the naive scan
    /// bitwise — assignments AND inertia bits — across random point sets,
    /// dims, centroid counts, thread counts, and hint regimes (none,
    /// garbage, realistic warm hints), including exact-duplicate centroids
    /// that force index tie-breaks.
    #[test]
    fn property_pruned_assign_matches_naive_bitwise() {
        crate::util::proptest::check(30, |g| {
            let n = g.usize_in(3, 60);
            let d = g.usize_in(1, 24);
            let k = g.usize_in(1, 10.min(n));
            let mut pts = Mat::zeros(0, d);
            for _ in 0..n {
                pts.push_row(&g.vec_f32(d, -4.0, 4.0));
            }
            let mut cents = Mat::zeros(0, d);
            for c in 0..k {
                if c == 1 && g.bool() {
                    // duplicate of centroid 0: ties must break to index 0
                    let row = cents.row(0).to_vec();
                    cents.push_row(&row);
                } else {
                    cents.push_row(&g.vec_f32(d, -4.0, 4.0));
                }
            }
            let hints: Option<Vec<usize>> = match g.usize_in(0, 2) {
                0 => None,
                // Garbage hints, deliberately including out-of-range values
                // (>= k) to exercise the fallback-to-0 branch.
                1 => Some((0..n).map(|_| g.usize_in(0, 2 * k)).collect()),
                _ => Some(assign(&pts, &cents, 1).0), // realistic warm hints
            };
            let (want_a, want_i) = assign(&pts, &cents, 1);
            for threads in [1usize, 4, 8] {
                let (got_a, got_i, st) =
                    assign_pruned(&pts, &cents, threads, hints.as_deref());
                assert_eq!(got_a, want_a, "threads={threads} hints={hints:?}");
                assert_eq!(got_i.to_bits(), want_i.to_bits(), "inertia, threads={threads}");
                assert_eq!(st.pairs, (n * k) as u64);
                assert!(st.exact <= st.pairs);
            }
        });
    }

    #[test]
    fn fit_is_bitwise_identical_for_every_pruning_mode() {
        let (pts, _) = blobs(80, &[(0.0, 0.0), (6.0, 0.0), (0.0, 6.0), (6.0, 6.0)], 0.8, 21);
        let fit_with = |pruning: crate::cluster::Pruning, threads: usize| {
            let mut cfg = KmeansConfig::new(4);
            cfg.seed = 9;
            cfg.threads = threads;
            cfg.pruning = pruning;
            fit(&pts, &cfg)
        };
        let base = fit_with(crate::cluster::Pruning::Off, 1);
        for pruning in [crate::cluster::Pruning::Auto, crate::cluster::Pruning::Bounds] {
            for threads in [1usize, 4, 8] {
                let r = fit_with(pruning, threads);
                assert_eq!(r.assignments, base.assignments, "{pruning:?} t={threads}");
                assert_eq!(r.inertia.to_bits(), base.inertia.to_bits());
                assert_eq!(r.iters, base.iters);
                assert_eq!(r.centroids, base.centroids);
            }
        }
        // And the pruned run actually skipped work.
        let pruned = fit_with(crate::cluster::Pruning::Bounds, 1);
        assert!(
            pruned.stats.skip_rate() > 0.0,
            "bounds path skipped nothing: {:?}",
            pruned.stats
        );
    }

    #[test]
    fn pruned_hamerly_fast_path_on_separated_blobs() {
        // Tight, well-separated blobs + warm hints: almost every point must
        // resolve with a single exact evaluation.
        let (pts, _) = blobs(200, &[(0.0, 0.0), (40.0, 0.0), (0.0, 40.0), (40.0, 40.0)], 0.2, 22);
        let mut cfg = KmeansConfig::new(4);
        cfg.seed = 3;
        cfg.pruning = crate::cluster::Pruning::Bounds;
        let r = fit(&pts, &cfg);
        let (hints, _) = assign(&pts, &r.centroids, 1);
        let (a, _, st) = assign_pruned(&pts, &r.centroids, 1, Some(&hints));
        assert_eq!(a, hints);
        assert_eq!(st.exact, pts.rows() as u64, "fast path missed: {st:?}");
        assert!(st.skip_rate() > 0.5, "skip rate {:.3}", st.skip_rate());
    }

    #[test]
    fn pruned_assign_handles_non_finite_points_like_naive() {
        // NaN / huge rows produce NaN / +inf distances; naive `assign`
        // rejects those via `d < best_d` and falls back to (0, inf). The
        // pruned kernel must reproduce that bit-for-bit, with and without
        // hints (a hinted b0 whose distance is NaN must not win).
        let mut pts = Mat::zeros(0, 4);
        pts.push_row(&[f32::NAN, 0.0, 0.0, 0.0]); // NaN to every centroid
        pts.push_row(&[1.0, 1.0, 1.0, 1.0]);
        pts.push_row(&[f32::MAX, f32::MAX, 0.0, 0.0]); // sqdist overflows
        pts.push_row(&[-1.0, 2.0, 0.5, 0.0]);
        let cents = Mat::from_rows(&[
            vec![0.0, 0.0, 0.0, 0.0],
            vec![1.0, 1.0, 1.0, 0.0],
            vec![f32::NAN, 0.0, 0.0, 0.0], // NaN centroid
        ]);
        let (want_a, want_i) = assign(&pts, &cents, 1);
        for hints in [None, Some(vec![2usize, 2, 2, 2]), Some(vec![1, 0, 1, 0])] {
            let (got_a, got_i, _) = assign_pruned(&pts, &cents, 1, hints.as_deref());
            assert_eq!(got_a, want_a, "hints={hints:?}");
            assert_eq!(got_i.to_bits(), want_i.to_bits(), "hints={hints:?}");
        }

        // Overflow boundary: the inter-centroid distance overflows an f32
        // lane to +∞ ((1.9e19)² > f32::MAX; dim ≥ 8 so the lane loop runs,
        // not the f64 tail) while the point's distances stay finite — an ∞
        // bound must NOT prune (it proves nothing about the true gap).
        // Here c1 really is nearer to x than the hinted c0.
        let mut row_x = vec![0.0f32; 8];
        row_x[0] = 1.0e19;
        let mut row_c1 = vec![0.0f32; 8];
        row_c1[0] = 1.9e19;
        let mut pts2 = Mat::zeros(0, 8);
        pts2.push_row(&row_x);
        let cents2 = Mat::from_rows(&[vec![0.0f32; 8], row_c1]);
        let (want_a2, want_i2) = assign(&pts2, &cents2, 1);
        assert_eq!(want_a2, vec![1]); // sanity: naive picks the near one
        for hints in [None, Some(vec![0usize])] {
            let (got_a2, got_i2, _) = assign_pruned(&pts2, &cents2, 1, hints.as_deref());
            assert_eq!(got_a2, want_a2, "overflow case, hints={hints:?}");
            assert_eq!(got_i2.to_bits(), want_i2.to_bits());
        }

        // Hamerly fast-path overflow hole (k = 3, dim 9 so the 9th
        // coordinate rides the f64 tail): cc2[c0][c1] overflows an f32
        // lane to +∞ while cc2[c0][c2] is a huge FINITE tail value — a min
        // over only the finite entries would let the fast path prune c1,
        // the true nearest. s must treat the whole row as unusable.
        let mut x = vec![0.0f32; 9];
        x[0] = 1.0e19;
        let mut c_best = vec![0.0f32; 9];
        c_best[8] = 1.0e30;
        let mut c_near = vec![0.0f32; 9];
        c_near[0] = 2.0e19; // lane (2e19)² overflows f32 in cc2[c0][c1]
        let mut c_far = vec![0.0f32; 9];
        c_far[8] = 5.0e30; // tail (4e30)² = 1.6e61, finite in f64
        let mut pts3 = Mat::zeros(0, 9);
        pts3.push_row(&x);
        let cents3 = Mat::from_rows(&[c_best, c_near, c_far]);
        let (want_a3, want_i3) = assign(&pts3, &cents3, 1);
        assert_eq!(want_a3, vec![1]); // sanity: naive picks c_near
        for hints in [None, Some(vec![0usize])] {
            let (got_a3, got_i3, _) = assign_pruned(&pts3, &cents3, 1, hints.as_deref());
            assert_eq!(got_a3, want_a3, "fast-path overflow case, hints={hints:?}");
            assert_eq!(got_i3.to_bits(), want_i3.to_bits());
        }
    }

    #[test]
    fn empty_cluster_repair_is_nan_safe_and_reseeds_distinct_points() {
        // A NaN coordinate used to panic the repair sort
        // (`partial_cmp().unwrap()`); `total_cmp` must survive it, and two
        // empty clusters must land on two different points.
        let mut pts = Mat::zeros(0, 2);
        pts.push_row(&[f32::NAN, 0.0]);
        for i in 0..6 {
            pts.push_row(&[i as f32, 1.0]);
        }
        let prev = Mat::from_rows(&[
            vec![0.0, 0.0],
            vec![100.0, 100.0],
            vec![200.0, 200.0],
            vec![300.0, 300.0],
        ]);
        // Clusters 2 and 3 are empty; the NaN distances (the NaN row and
        // everything measured against its NaN-poisoned cluster-0 mean)
        // must not panic AND must rank below every finite distance: the
        // re-seeds land on the farthest finite points of cluster 1 (tied
        // at distance 1 → lower index first), never on a NaN row.
        let assignments = vec![0, 0, 0, 1, 1, 1, 0];
        let out = update_centroids(&pts, &assignments, 4, &prev);
        assert_eq!(out.rows(), 4);
        assert_eq!(out.row(2), &[2.0, 1.0]);
        assert_eq!(out.row(3), &[4.0, 1.0]);

        // All-finite case with tied distances: the two empties must be
        // re-seeded to two DIFFERENT points (distance desc, index asc).
        let pts2 = Mat::from_rows(&[
            vec![0.0, 0.0],
            vec![10.0, 0.0],
            vec![-10.0, 0.0], // same distance to centroid 0 as point 1
            vec![0.0, 1.0],
        ]);
        let assignments2 = vec![0, 0, 0, 0];
        let out2 = update_centroids(&pts2, &assignments2, 3, &prev);
        assert_ne!(out2.row(1), out2.row(2), "two empties re-seeded to the same point");
        // Tie at max distance: stable order picks the lower index first.
        assert_eq!(out2.row(1), &[10.0, 0.0]);
        assert_eq!(out2.row(2), &[-10.0, 0.0]);
    }

    #[test]
    fn quantized_fit_matches_exact_oracle_on_blobs() {
        // The quantized-path acceptance oracle at test scale: int8-store
        // clustering must agree with the exact f32 fit to ARI ≥ 0.95 on
        // planted blobs (and with the ground truth).
        let (pts, truth) = blobs(60, &[(0.0, 0.0), (10.0, 10.0), (-10.0, 10.0), (10.0, -10.0)], 0.4, 31);
        let q = QuantMat::from_mat(&pts);
        let mut cfg = KmeansConfig::new(4);
        cfg.seed = 5;
        let exact = fit(&pts, &cfg);
        let quant = fit_quantized(&q, &cfg);
        let ari_vs_exact =
            crate::util::stats::adjusted_rand_index(&quant.assignments, &exact.assignments);
        let ari_vs_truth = crate::util::stats::adjusted_rand_index(&quant.assignments, &truth);
        assert!(ari_vs_exact >= 0.95, "ARI vs exact {ari_vs_exact}");
        assert!(ari_vs_truth >= 0.95, "ARI vs truth {ari_vs_truth}");
    }

    #[test]
    fn quantized_assign_is_bitwise_thread_invariant() {
        let (pts, _) = blobs(70, &[(0.0, 0.0), (6.0, 0.0), (0.0, 6.0)], 1.0, 32);
        let q = QuantMat::from_mat(&pts);
        let cents = Mat::from_rows(&[vec![0.0, 0.0], vec![6.0, 0.0], vec![0.0, 6.0]]);
        let (a1, i1, s1) = assign_quantized(&q, &cents, 1, None);
        for threads in [4usize, 8] {
            let (a, i, s) = assign_quantized(&q, &cents, threads, None);
            assert_eq!(a, a1, "threads={threads}");
            assert_eq!(i.to_bits(), i1.to_bits(), "threads={threads}");
            assert_eq!((s.pairs, s.exact), (s1.pairs, s1.exact), "threads={threads}");
        }
        // Warm hints change work, never the result.
        let (ah, ih, sh) = assign_quantized(&q, &cents, 1, Some(&a1));
        assert_eq!(ah, a1);
        assert_eq!(ih.to_bits(), i1.to_bits());
        assert!(sh.exact <= s1.exact, "hints did not help: {sh:?} vs {s1:?}");
        // And the norm screen actually skips work on separated data.
        assert!(s1.skip_rate() > 0.0, "screen skipped nothing: {s1:?}");
    }

    /// The quantized assignment against the *dequantized* matrix oracle:
    /// feeding assign() the materialized dequantized points must produce
    /// the same assignments (distances differ only in f32-lane vs
    /// exact-affine rounding; planted separations dwarf that).
    #[test]
    fn property_quantized_assign_matches_dequantized_naive() {
        crate::util::proptest::check(15, |g| {
            let n = g.usize_in(4, 40);
            let d = g.usize_in(1, 16);
            let k = g.usize_in(1, 5.min(n));
            let mut pts = Mat::zeros(0, d);
            for _ in 0..n {
                pts.push_row(&g.vec_f32(d, -4.0, 4.0));
            }
            let mut cents = Mat::zeros(0, d);
            for _ in 0..k {
                cents.push_row(&g.vec_f32(d, -4.0, 4.0));
            }
            let q = QuantMat::from_mat(&pts);
            let deq = q.dequantize();
            let (want_a, _) = assign(&deq, &cents, 1);
            let (got_a, _, st) = assign_quantized(&q, &cents, 1, None);
            assert_eq!(st.pairs, (n * k) as u64);
            // Allow disagreement only where the two nearest centroids are
            // within the rounding band of each other.
            for i in 0..n {
                if got_a[i] == want_a[i] {
                    continue;
                }
                let dg = sqdist(deq.row(i), cents.row(got_a[i]));
                let dw = sqdist(deq.row(i), cents.row(want_a[i]));
                assert!(
                    (dg - dw).abs() <= 1e-4 * (1.0 + dw.abs()),
                    "point {i}: quant chose {} (d {dg}), oracle {} (d {dw})",
                    got_a[i],
                    want_a[i]
                );
            }
        });
    }

    #[test]
    fn fit_quantized_is_deterministic_and_repairs_empties() {
        let (pts, _) = blobs(30, &[(0.0, 0.0), (5.0, 5.0)], 0.8, 33);
        let q = QuantMat::from_mat(&pts);
        let mut cfg = KmeansConfig::new(2);
        cfg.seed = 7;
        let a = fit_quantized(&q, &cfg);
        let mut cfg8 = cfg.clone();
        cfg8.threads = 8;
        let b = fit_quantized(&q, &cfg8);
        assert_eq!(a.assignments, b.assignments);
        assert_eq!(a.inertia.to_bits(), b.inertia.to_bits());
        assert_eq!(a.centroids, b.centroids);
        // k close to n forces empty-cluster repair through the quantized
        // update; it must stay finite and deterministic.
        let mut small = Mat::zeros(0, 2);
        for _ in 0..6 {
            small.push_row(&[1.0, 2.0]);
        }
        small.push_row(&[9.0, 9.0]);
        let qs = QuantMat::from_mat(&small);
        let mut cfg_rep = KmeansConfig::new(4);
        cfg_rep.seed = 1;
        let r = fit_quantized(&qs, &cfg_rep);
        assert_eq!(r.assignments.len(), 7);
        assert!(r.centroids.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn property_assignments_are_nearest() {
        crate::util::proptest::check(10, |g| {
            let n = g.usize_in(10, 60);
            let d = g.usize_in(1, 8);
            let k = g.usize_in(1, 4.min(n));
            let mut m = Mat::zeros(0, d);
            for _ in 0..n {
                m.push_row(&g.vec_f32(d, -5.0, 5.0));
            }
            let mut cfg = KmeansConfig::new(k);
            cfg.seed = g.case as u64;
            let res = fit(&m, &cfg);
            // Invariant: every point's assigned centroid is (one of) its nearest.
            for i in 0..n {
                let assigned_d = m.sqdist_row(i, res.centroids.row(res.assignments[i]));
                for c in 0..k {
                    let d2 = m.sqdist_row(i, res.centroids.row(c));
                    assert!(assigned_d <= d2 + 1e-5, "point {i} not nearest");
                }
            }
        });
    }
}
