//! K-means device clustering (paper §4.2): k-means++ seeding + Lloyd
//! iterations, parallel over points. This is the server-side clustering
//! engine for the proposed encoder summaries; `runtime::KmeansHlo` offers
//! the same Lloyd step through the AOT Pallas-kernel artifact.

use crate::util::mat::{sqdist, Mat};
use crate::util::parallel::{default_threads, map_chunks};
use crate::util::rng::Rng;

/// K-means configuration.
#[derive(Debug, Clone)]
pub struct KmeansConfig {
    pub k: usize,
    pub max_iters: usize,
    /// Relative inertia improvement below which we stop.
    pub tol: f64,
    pub seed: u64,
    pub threads: usize,
}

impl KmeansConfig {
    pub fn new(k: usize) -> Self {
        KmeansConfig { k, max_iters: 50, tol: 1e-4, seed: 0, threads: default_threads() }
    }
}

/// Result of a K-means fit.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub centroids: Mat,
    pub assignments: Vec<usize>,
    pub inertia: f64,
    pub iters: usize,
}

/// k-means++ initialization (Arthur & Vassilvitskii 2007).
pub fn kmeanspp_init(points: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = points.rows();
    assert!(n >= k, "kmeans++: n={n} < k={k}");
    let mut centroids = Mat::zeros(0, points.cols());
    let first = rng.below(n as u64) as usize;
    centroids.push_row(points.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| points.sqdist_row(i, centroids.row(0))).collect();
    for _ in 1..k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all points identical to chosen centroids: pick uniformly
            rng.below(n as u64) as usize
        } else {
            rng.weighted_index(&d2)
        };
        centroids.push_row(points.row(next));
        let c = centroids.rows() - 1;
        for i in 0..n {
            let d = points.sqdist_row(i, centroids.row(c));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Assign each point to its nearest centroid; returns (assignments, inertia).
///
/// The inertia is reduced serially in point order from per-point values, NOT
/// from per-chunk partial sums: f64 addition is non-associative, so chunked
/// partials would make the total (and anything derived from it, like Lloyd's
/// convergence round) depend on the thread count. This keeps the whole
/// clustering pipeline bitwise thread-count invariant.
pub fn assign(points: &Mat, centroids: &Mat, threads: usize) -> (Vec<usize>, f64) {
    let n = points.rows();
    let k = centroids.rows();
    let chunks = map_chunks(n, threads, |lo, hi| {
        let mut a = Vec::with_capacity(hi - lo);
        let mut d2 = Vec::with_capacity(hi - lo);
        for i in lo..hi {
            let row = points.row(i);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k {
                let d = sqdist(row, centroids.row(c));
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            a.push(best);
            d2.push(best_d);
        }
        (a, d2)
    });
    let mut assignments = Vec::with_capacity(n);
    let mut inertia = 0.0f64;
    for (a, d2) in chunks {
        assignments.extend(a);
        for d in d2 {
            inertia += d;
        }
    }
    (assignments, inertia)
}

/// Recompute centroids as cluster means; empty clusters are re-seeded to the
/// point farthest from its centroid (standard Lloyd repair).
fn update_centroids(points: &Mat, assignments: &[usize], k: usize, prev: &Mat) -> Mat {
    let d = points.cols();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0usize; k];
    for (i, &a) in assignments.iter().enumerate() {
        counts[a] += 1;
        let row = points.row(i);
        let dst = &mut sums[a * d..(a + 1) * d];
        for (s, &v) in dst.iter_mut().zip(row) {
            *s += v as f64;
        }
    }
    let mut out = Mat::zeros(k, d);
    let mut empties = Vec::new();
    for c in 0..k {
        if counts[c] == 0 {
            empties.push(c);
            out.row_mut(c).copy_from_slice(prev.row(c));
        } else {
            let inv = 1.0 / counts[c] as f64;
            for (j, v) in out.row_mut(c).iter_mut().enumerate() {
                *v = (sums[c * d + j] * inv) as f32;
            }
        }
    }
    // Re-seed empty clusters to the farthest points.
    if !empties.is_empty() {
        let mut far: Vec<(f64, usize)> = assignments
            .iter()
            .enumerate()
            .map(|(i, &a)| (points.sqdist_row(i, out.row(a)), i))
            .collect();
        far.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (e, c) in empties.into_iter().enumerate() {
            if e < far.len() {
                let idx = far[e].1;
                let row = points.row(idx).to_vec();
                out.row_mut(c).copy_from_slice(&row);
            }
        }
    }
    out
}

/// Full Lloyd fit.
pub fn fit(points: &Mat, cfg: &KmeansConfig) -> KmeansResult {
    assert!(points.rows() >= cfg.k, "kmeans: fewer points than clusters");
    let mut rng = Rng::new(cfg.seed);
    let mut centroids = kmeanspp_init(points, cfg.k, &mut rng);
    let mut prev_inertia = f64::INFINITY;
    let mut assignments = Vec::new();
    let mut inertia = 0.0;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        let (a, i) = assign(points, &centroids, cfg.threads);
        assignments = a;
        inertia = i;
        iters = it + 1;
        if prev_inertia.is_finite() && (prev_inertia - inertia) <= cfg.tol * prev_inertia.max(1e-12)
        {
            break;
        }
        prev_inertia = inertia;
        centroids = update_centroids(points, &assignments, cfg.k, &centroids);
    }
    KmeansResult { centroids, assignments, inertia, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, centers: &[(f32, f32)], spread: f32, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(0, 2);
        let mut truth = Vec::new();
        for (g, &(cx, cy)) in centers.iter().enumerate() {
            for _ in 0..n_per {
                m.push_row(&[
                    cx + spread * rng.normal() as f32,
                    cy + spread * rng.normal() as f32,
                ]);
                truth.push(g);
            }
        }
        (m, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (pts, truth) = blobs(50, &[(0.0, 0.0), (10.0, 10.0), (-10.0, 10.0)], 0.3, 1);
        let res = fit(&pts, &KmeansConfig::new(3));
        let ari = crate::util::stats::adjusted_rand_index(&res.assignments, &truth);
        assert!(ari > 0.99, "ari={ari}");
        assert!(res.inertia < 150.0 * 2.0 * 0.3 * 0.3 * 4.0);
    }

    #[test]
    fn inertia_nonincreasing_over_restarts_of_same_seed() {
        let (pts, _) = blobs(40, &[(0.0, 0.0), (5.0, 5.0)], 1.0, 2);
        let a = fit(&pts, &KmeansConfig::new(2));
        let b = fit(&pts, &KmeansConfig::new(2));
        assert_eq!(a.assignments, b.assignments); // deterministic
        assert!((a.inertia - b.inertia).abs() < 1e-9);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let (pts, _) = blobs(1, &[(0.0, 0.0), (1.0, 1.0), (2.0, 2.0)], 0.0, 3);
        let res = fit(&pts, &KmeansConfig::new(3));
        assert!(res.inertia < 1e-9);
        let mut a = res.assignments.clone();
        a.sort_unstable();
        a.dedup();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn identical_points_dont_crash() {
        let mut m = Mat::zeros(0, 3);
        for _ in 0..20 {
            m.push_row(&[1.0, 2.0, 3.0]);
        }
        let res = fit(&m, &KmeansConfig::new(4));
        assert!(res.inertia < 1e-9);
        assert_eq!(res.assignments.len(), 20);
    }

    #[test]
    fn single_cluster() {
        let (pts, _) = blobs(30, &[(2.0, 2.0)], 0.5, 4);
        let res = fit(&pts, &KmeansConfig::new(1));
        assert!(res.assignments.iter().all(|&a| a == 0));
        // centroid near (2,2)
        assert!((res.centroids.row(0)[0] - 2.0).abs() < 0.3);
    }

    #[test]
    fn parallel_matches_serial() {
        let (pts, _) = blobs(100, &[(0.0, 0.0), (8.0, 0.0), (0.0, 8.0)], 1.0, 5);
        let mut cfg1 = KmeansConfig::new(3);
        cfg1.threads = 1;
        let mut cfg8 = KmeansConfig::new(3);
        cfg8.threads = 8;
        let a = fit(&pts, &cfg1);
        let b = fit(&pts, &cfg8);
        assert_eq!(a.assignments, b.assignments);
        assert!((a.inertia - b.inertia).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "fewer points")]
    fn too_few_points_panics() {
        let (pts, _) = blobs(1, &[(0.0, 0.0)], 0.0, 6);
        fit(&pts, &KmeansConfig::new(5));
    }

    #[test]
    fn property_assignments_are_nearest() {
        crate::util::proptest::check(10, |g| {
            let n = g.usize_in(10, 60);
            let d = g.usize_in(1, 8);
            let k = g.usize_in(1, 4.min(n));
            let mut m = Mat::zeros(0, d);
            for _ in 0..n {
                m.push_row(&g.vec_f32(d, -5.0, 5.0));
            }
            let mut cfg = KmeansConfig::new(k);
            cfg.seed = g.case as u64;
            let res = fit(&m, &cfg);
            // Invariant: every point's assigned centroid is (one of) its nearest.
            for i in 0..n {
                let assigned_d = m.sqdist_row(i, res.centroids.row(res.assignments[i]));
                for c in 0..k {
                    let d2 = m.sqdist_row(i, res.centroids.row(c));
                    assert!(assigned_d <= d2 + 1e-5, "point {i} not nearest");
                }
            }
        });
    }
}
