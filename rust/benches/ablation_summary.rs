//! Bench E7 — summary-design ablations the paper discusses in §4.1 / §5:
//!
//!  * coreset size k: summary time + downstream clustering quality (ARI);
//!    encoder artifacts are compiled at k in {32, 128, 512} (FEMNIST);
//!  * dimension-reduction method: encoder vs PCA vs JL random projection
//!    at matched output dims — the "(1) GPU-friendly (2) spatially aware"
//!    trade-off the paper argues for, measured as clustering quality.
//!
//!     cargo bench --bench ablation_summary

use feddde::cluster::kmeans;
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, JlSummary, PcaBasis, PcaSummary, SummaryEngine};
use feddde::util::bench::Bencher;
use feddde::util::mat::Mat;
use feddde::util::rng::Rng;
use feddde::util::stats;

fn fleet_summaries(
    spec: &DatasetSpec,
    se: &dyn SummaryEngine,
    engine: &Engine,
    partition: &Partition,
    generator: &Generator,
) -> Mat {
    let mut m = Mat::zeros(0, se.dim());
    for part in &partition.clients {
        let ds = generator.client_dataset(part, 0);
        let mut rng = Rng::substream(spec.seed, &[part.client_id as u64]);
        let (v, _) = se.summarize(engine, &ds, &mut rng).expect("summarize");
        m.push_row(&v);
    }
    m
}

fn cluster_ari(spec: &DatasetSpec, m: &Mat, blocks: &[(usize, usize)], truth: &[usize]) -> f64 {
    let balanced = feddde::cluster::balance_blocks(m, blocks);
    let mut cfg = kmeans::KmeansConfig::new(spec.n_groups);
    cfg.seed = 5;
    let res = kmeans::fit(&balanced, &cfg);
    stats::adjusted_rand_index(&res.assignments, truth)
}

fn main() {
    println!("ablation_summary — coreset size & dimension-reduction method\n");
    let spec = DatasetSpec::femnist().with_clients(72);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let truth = partition.group_truth();
    let engine = Engine::open_default().expect("artifacts");
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    std::fs::create_dir_all("results").ok();
    let mut rows = vec!["# variant\tsummary_mean_s\tari".to_string()];

    // --- coreset size sweep (encoder artifacts compiled per k) -------------
    println!("coreset size k (encoder summary):");
    for k in [32usize, 128, 512] {
        let se = EncoderSummary::with_k(&spec, k);
        let part0 = &partition.clients[0];
        let ds = generator.client_dataset(part0, 0);
        let mut rng = Rng::new(1);
        let meas = b.bench(&format!("encoder/k{k}/summarize"), || {
            let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
            std::hint::black_box(v.len());
        });
        let m = fleet_summaries(&spec, &se, &engine, &partition, &generator);
        let ari = cluster_ari(&spec, &m, &se.blocks(), &truth);
        println!("    k={k:<4} ARI={ari:.3}");
        rows.push(format!("encoder_k{k}\t{:.6}\t{ari:.4}", meas.mean_secs()));
    }

    // --- dimension-reduction method at matched dims -------------------------
    println!("\ndimension-reduction method (fixed k=128, H=64):");
    let variants: Vec<(String, Box<dyn SummaryEngine>)> = {
        // PCA basis fitted on a server-side sample of raw images.
        let mut sample = Mat::zeros(0, spec.flat_dim());
        for part in partition.clients.iter().take(12) {
            let ds = generator.client_dataset(part, 0);
            for i in 0..ds.n.min(24) {
                sample.push_row(ds.image(i));
            }
        }
        let basis = PcaBasis::fit(&sample, spec.feature_dim, 6, 9);
        vec![
            ("encoder".into(), Box::new(EncoderSummary::new(&spec)) as Box<dyn SummaryEngine>),
            ("jl".into(), Box::new(JlSummary::new(&spec))),
            ("pca".into(), Box::new(PcaSummary::new(&spec, basis))),
        ]
    };
    for (tag, se) in &variants {
        let part0 = &partition.clients[0];
        let ds = generator.client_dataset(part0, 0);
        let mut rng = Rng::new(2);
        let meas = b.bench(&format!("reduce/{tag}/summarize"), || {
            let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
            std::hint::black_box(v.len());
        });
        let m = fleet_summaries(&spec, se.as_ref(), &engine, &partition, &generator);
        let ari = cluster_ari(&spec, &m, &se.blocks(), &truth);
        println!("    {tag:<8} ARI={ari:.3}");
        rows.push(format!("{tag}\t{:.6}\t{ari:.4}", meas.mean_secs()));
    }

    std::fs::write("results/ablation_summary.tsv", rows.join("\n") + "\n").unwrap();
    println!("\nwrote results/ablation_summary.tsv");
}
