//! Bench E2 — Table 2 left half: per-client summary-computation time for
//! P(y), P(X|y), and the proposed Encoder summary on both dataset families,
//! plus the fleet-refresh parallel-scaling section (host seconds to refresh
//! a 1000-client fleet at 1 thread vs all cores — the ISSUE-2 acceptance
//! line: >= 2x reduction on a multi-core host).
//!
//!     cargo bench --bench table2_summary          # CI scale
//!     FEDDDE_BENCH_FULL=1 cargo bench ...         # paper-scale fleets
//!
//! Reports host kernel time per client workload size (the simulator scales
//! these by device factors; see examples/overhead_report.rs for the full
//! Table 2 with fleet simulation). Results land in results/table2_summary.tsv.
//! The per-client artifact section needs the AOT bundle; the refresh section
//! runs everywhere (pure-Rust JL engine).

use feddde::cluster::ClusterBackend;
use feddde::coordinator::{FleetRefresher, RefreshOptions};
use feddde::data::{DatasetSpec, DriftSchedule, Generator, Partition};
use feddde::device::FleetModel;
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, JlSummary, PxySummary, PySummary, SummaryEngine};
use feddde::util::bench::{full_scale, Bencher};
use feddde::util::parallel::default_threads;
use feddde::util::rng::Rng;

fn bench_dataset(b: &mut Bencher, name: &str) {
    let preset = DatasetSpec::by_name(name).unwrap();
    let spec = if full_scale() { preset.clone() } else { preset.with_clients(64) };
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let engine = Engine::open_default().expect("artifacts missing: run `make artifacts`");

    // Representative clients: smallest, median, largest by sample count.
    let mut order: Vec<usize> = (0..spec.n_clients).collect();
    order.sort_by_key(|&i| partition.clients[i].n_samples);
    let picks = [
        ("min", order[0]),
        ("med", order[order.len() / 2]),
        ("max", order[order.len() - 1]),
    ];

    let engines: Vec<Box<dyn SummaryEngine>> = vec![
        Box::new(PySummary::new(&spec)),
        Box::new(PxySummary::new(&spec)),
        Box::new(EncoderSummary::new(&spec)),
    ];
    for se in &engines {
        for (tag, idx) in picks {
            let part = &partition.clients[idx];
            let ds = generator.client_dataset(part, 0);
            let mut rng = Rng::new(idx as u64);
            b.bench(
                &format!("{name}/{}/client_{tag}_n{}", se.name(), ds.n),
                || {
                    let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
                    std::hint::black_box(v.len());
                },
            );
        }
    }
}

/// Fleet-refresh scaling: serial vs parallel summarization of a 1000-client
/// fleet through the refresh subsystem (JL engine: pure Rust, runs without
/// artifacts; the parallel structure is identical for artifact engines).
fn bench_fleet_refresh(b: &mut Bencher) {
    let n = if full_scale() { 2800 } else { 1000 };
    let spec = DatasetSpec::femnist().with_clients(n);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let fleet = FleetModel::default().sample_fleet(spec.n_clients);
    let engine = Engine::without_artifacts().expect("manifest-free engine");
    let jl = JlSummary::new(&spec);
    let drift = DriftSchedule::none();

    let mut host_secs = Vec::new();
    for threads in [1usize, default_threads()] {
        let mut refresher = FleetRefresher::new(RefreshOptions {
            threads,
            backend: ClusterBackend::Minibatch,
            use_cache: false,
            ..Default::default()
        });
        let mut last = 0.0;
        b.bench_once(&format!("refresh_fleet/jl/N{n}/threads{threads}"), || {
            let r = refresher
                .refresh(
                    &engine, &jl, &partition, &generator, &fleet, &drift, 0,
                    spec.n_groups, 7,
                )
                .expect("refresh");
            last = r.host_secs;
            std::hint::black_box(r.summaries.rows());
        });
        host_secs.push(last);
    }
    if host_secs.len() == 2 && host_secs[1] > 0.0 {
        println!(
            "    -> refresh host-seconds speedup at {} threads: {:.2}x (target >= 2x)",
            default_threads(),
            host_secs[0] / host_secs[1]
        );
    }

    // Incremental refresh: steady-state cost with the summary cache on.
    let mut cached = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Minibatch,
        ..Default::default()
    });
    cached
        .refresh(&engine, &jl, &partition, &generator, &fleet, &drift, 0, spec.n_groups, 7)
        .expect("cold refresh");
    b.bench(&format!("refresh_fleet/jl/N{n}/cached_no_drift"), || {
        let r = cached
            .refresh(&engine, &jl, &partition, &generator, &fleet, &drift, 1, spec.n_groups, 7)
            .expect("cached refresh");
        assert!(r.recomputed.is_empty());
        std::hint::black_box(r.clusters.len());
    });
}

fn main() {
    println!("table2_summary — per-client summary time (host kernel seconds)\n");
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    match Engine::open_default() {
        Ok(_) if Engine::runtime_available() => {
            bench_dataset(&mut b, "femnist");
            bench_dataset(&mut b, "openimage");
        }
        _ => println!(
            "(skipping per-client artifact section: AOT bundle or PJRT backend missing)\n"
        ),
    }
    println!("fleet refresh scaling (pure-Rust JL engine):");
    bench_fleet_refresh(&mut b);
    std::fs::create_dir_all("results").ok();
    b.write_tsv("results/table2_summary.tsv").unwrap();
    println!("\nwrote results/table2_summary.tsv");
}
