//! Bench E2 — Table 2 left half: per-client summary-computation time for
//! P(y), P(X|y), and the proposed Encoder summary on both dataset families.
//!
//!     cargo bench --bench table2_summary          # CI scale
//!     FEDDDE_BENCH_FULL=1 cargo bench ...         # paper-scale fleets
//!
//! Reports host kernel time per client workload size (the simulator scales
//! these by device factors; see examples/overhead_report.rs for the full
//! Table 2 with fleet simulation). Results land in results/table2_summary.tsv.

use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, PxySummary, PySummary, SummaryEngine};
use feddde::util::bench::{full_scale, Bencher};
use feddde::util::rng::Rng;

fn bench_dataset(b: &mut Bencher, name: &str) {
    let preset = DatasetSpec::by_name(name).unwrap();
    let spec = if full_scale() { preset.clone() } else { preset.with_clients(64) };
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let engine = Engine::open_default().expect("artifacts missing: run `make artifacts`");

    // Representative clients: smallest, median, largest by sample count.
    let mut order: Vec<usize> = (0..spec.n_clients).collect();
    order.sort_by_key(|&i| partition.clients[i].n_samples);
    let picks = [
        ("min", order[0]),
        ("med", order[order.len() / 2]),
        ("max", order[order.len() - 1]),
    ];

    let engines: Vec<Box<dyn SummaryEngine>> = vec![
        Box::new(PySummary::new(&spec)),
        Box::new(PxySummary::new(&spec)),
        Box::new(EncoderSummary::new(&spec)),
    ];
    for se in &engines {
        for (tag, idx) in picks {
            let part = &partition.clients[idx];
            let ds = generator.client_dataset(part, 0);
            let mut rng = Rng::new(idx as u64);
            b.bench(
                &format!("{name}/{}/client_{tag}_n{}", se.name(), ds.n),
                || {
                    let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
                    std::hint::black_box(v.len());
                },
            );
        }
    }
}

fn main() {
    println!("table2_summary — per-client summary time (host kernel seconds)\n");
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    bench_dataset(&mut b, "femnist");
    bench_dataset(&mut b, "openimage");
    std::fs::create_dir_all("results").ok();
    b.write_tsv("results/table2_summary.tsv").unwrap();
    println!("\nwrote results/table2_summary.tsv");
}
