//! Bench E2 — Table 2 left half: per-client summary-computation time for
//! P(y), P(X|y), and the proposed Encoder summary on both dataset families,
//! the fleet-refresh parallel-scaling section (host seconds to refresh a
//! 1000-client fleet at 1 thread vs all cores — the ISSUE-2 acceptance
//! line: >= 2x reduction on a multi-core host), and the streaming-refresh
//! memory section, which measures the fused generate→coreset→project
//! pipeline against the materialize-everything baseline through a counting
//! global allocator and emits machine-readable
//! `results/BENCH_refresh.json` (clients/sec, bytes allocated per client,
//! peak live heap, store arena bytes). The ISSUE-4 acceptance lines: >= 5x
//! fewer bytes generated per client fused-vs-materialized, and a cold
//! 10x-fleet fused refresh peaking under the materialized run's peak. A
//! fourth phase runs the fused fleet on the int8-quantized store
//! (`store_quantized`) and quotes resident store bytes/client (target:
//! >= 4x reduction) plus clustering ARI vs the exact run (target >= 0.95).
//!
//!     cargo bench --bench table2_summary          # CI scale
//!     FEDDDE_BENCH_FULL=1 cargo bench ...         # paper-scale fleets
//!     FEDDDE_BENCH_REFRESH_ONLY=1 ...             # just BENCH_refresh.json
//!                                                 #   (`make bench-smoke`)
//!
//! Reports host kernel time per client workload size (the simulator scales
//! these by device factors; see examples/overhead_report.rs for the full
//! Table 2 with fleet simulation). Results land in results/table2_summary.tsv.
//! The per-client artifact section needs the AOT bundle; the refresh
//! sections run everywhere (pure-Rust JL engine).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use feddde::cluster::ClusterBackend;
use feddde::coordinator::{FleetRefresher, RefreshOptions};
use feddde::data::{DatasetSpec, DriftSchedule, Generator, Partition};
use feddde::device::FleetModel;
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, JlSummary, PxySummary, PySummary, SummaryEngine};
use feddde::util::bench::{full_scale, Bencher};
use feddde::util::parallel::default_threads;
use feddde::util::rng::Rng;
use feddde::util::stats;

/// Counting allocator: total bytes ever allocated, live bytes, and a
/// resettable live-bytes high-water mark. This is what turns "the fused
/// path doesn't materialize raw data" from prose into numbers.
struct CountingAlloc;

static TOTAL: AtomicU64 = AtomicU64::new(0);
static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            TOTAL.fetch_add(layout.size() as u64, Ordering::Relaxed);
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size > layout.size() {
                let grow = new_size - layout.size();
                TOTAL.fetch_add(grow as u64, Ordering::Relaxed);
                let live = LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
                PEAK.fetch_max(live, Ordering::Relaxed);
            } else {
                LIVE.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// (total_allocated_so_far, live_at_start) — call at phase start after
/// resetting the peak to the current live level.
fn alloc_phase_start() -> (u64, usize) {
    let live = LIVE.load(Ordering::Relaxed);
    PEAK.store(live, Ordering::Relaxed);
    (TOTAL.load(Ordering::Relaxed), live)
}

/// (bytes allocated during the phase, peak live bytes above the phase's
/// starting level).
fn alloc_phase_end(start: (u64, usize)) -> (u64, usize) {
    let allocated = TOTAL.load(Ordering::Relaxed) - start.0;
    let peak = PEAK.load(Ordering::Relaxed).saturating_sub(start.1);
    (allocated, peak)
}

fn bench_dataset(b: &mut Bencher, name: &str) {
    let preset = DatasetSpec::by_name(name).unwrap();
    let spec = if full_scale() { preset.clone() } else { preset.with_clients(64) };
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let engine = Engine::open_default().expect("artifacts missing: run `make artifacts`");

    // Representative clients: smallest, median, largest by sample count.
    let mut order: Vec<usize> = (0..spec.n_clients).collect();
    order.sort_by_key(|&i| partition.clients[i].n_samples);
    let picks = [
        ("min", order[0]),
        ("med", order[order.len() / 2]),
        ("max", order[order.len() - 1]),
    ];

    let engines: Vec<Box<dyn SummaryEngine>> = vec![
        Box::new(PySummary::new(&spec)),
        Box::new(PxySummary::new(&spec)),
        Box::new(EncoderSummary::new(&spec)),
    ];
    for se in &engines {
        for (tag, idx) in picks {
            let part = &partition.clients[idx];
            let ds = generator.client_dataset(part, 0);
            let mut rng = Rng::new(idx as u64);
            b.bench(
                &format!("{name}/{}/client_{tag}_n{}", se.name(), ds.n),
                || {
                    let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
                    std::hint::black_box(v.len());
                },
            );
        }
    }
}

/// Fleet-refresh scaling: serial vs parallel summarization of a 1000-client
/// fleet through the refresh subsystem (JL engine: pure Rust, runs without
/// artifacts; the parallel structure is identical for artifact engines).
fn bench_fleet_refresh(b: &mut Bencher) {
    let n = if full_scale() { 2800 } else { 1000 };
    let spec = DatasetSpec::femnist().with_clients(n);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let fleet = FleetModel::default().sample_fleet(spec.n_clients);
    let engine = Engine::without_artifacts().expect("manifest-free engine");
    let jl = JlSummary::new(&spec);
    let drift = DriftSchedule::none();

    let mut host_secs = Vec::new();
    for threads in [1usize, default_threads()] {
        let mut refresher = FleetRefresher::new(RefreshOptions {
            threads,
            backend: ClusterBackend::Minibatch,
            use_cache: false,
            ..Default::default()
        });
        let mut last = 0.0;
        b.bench_once(&format!("refresh_fleet/jl/N{n}/threads{threads}"), || {
            let r = refresher
                .refresh(
                    &engine, &jl, &partition, &generator, &fleet, &drift, 0,
                    spec.n_groups, 7,
                )
                .expect("refresh");
            last = r.host_secs;
            std::hint::black_box(r.summaries.rows());
        });
        host_secs.push(last);
    }
    if host_secs.len() == 2 && host_secs[1] > 0.0 {
        println!(
            "    -> refresh host-seconds speedup at {} threads: {:.2}x (target >= 2x)",
            default_threads(),
            host_secs[0] / host_secs[1]
        );
    }

    // Incremental refresh: steady-state cost with the summary store on.
    let mut cached = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Minibatch,
        ..Default::default()
    });
    cached
        .refresh(&engine, &jl, &partition, &generator, &fleet, &drift, 0, spec.n_groups, 7)
        .expect("cold refresh");
    b.bench(&format!("refresh_fleet/jl/N{n}/cached_no_drift"), || {
        let r = cached
            .refresh(&engine, &jl, &partition, &generator, &fleet, &drift, 1, spec.n_groups, 7)
            .expect("cached refresh");
        assert!(r.recomputed.is_empty());
        std::hint::black_box(r.clusters.len());
    });
}

/// Streaming-refresh workload: openimage-style clients (3072 px, ~228
/// samples each) with a compact summary (10 classes × 8 features), so the
/// measured memory is dominated by what this PR changes — raw-data
/// generation — not by the unavoidable `n_clients × dim` summary arena.
fn refresh_bench_spec(n: usize) -> DatasetSpec {
    let mut s = DatasetSpec::openimage().with_clients(n);
    s.name = "refresh-bench".into();
    s.classes = 10;
    s.feature_dim = 8;
    s.n_groups = 8;
    s.coreset_k = 64;
    s
}

struct RefreshPhase {
    n: usize,
    secs: f64,
    clients_per_sec: f64,
    bytes_per_client: f64,
    peak_live_bytes: usize,
    store_bytes: usize,
    store_param_bytes: usize,
    clusters: Vec<usize>,
}

/// One measured cold refresh over a fresh refresher.
fn run_refresh_phase(n: usize, fused: bool, emit: bool, quantized: bool) -> RefreshPhase {
    let spec = refresh_bench_spec(n);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let fleet = FleetModel::default().sample_fleet(spec.n_clients);
    let engine = Engine::without_artifacts().expect("manifest-free engine");
    let jl = JlSummary::new(&spec);
    let drift = DriftSchedule::none();
    let mut refresher = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Minibatch,
        fused,
        emit_summaries: emit,
        store_quantized: quantized,
        ..Default::default()
    });
    let start = alloc_phase_start();
    let t0 = std::time::Instant::now();
    let r = refresher
        .refresh(&engine, &jl, &partition, &generator, &fleet, &drift, 0, spec.n_groups, 7)
        .expect("refresh");
    let secs = t0.elapsed().as_secs_f64();
    let (allocated, peak) = alloc_phase_end(start);
    std::hint::black_box(r.clusters.len());
    RefreshPhase {
        n,
        secs,
        clients_per_sec: n as f64 / secs.max(1e-9),
        bytes_per_client: allocated as f64 / n as f64,
        peak_live_bytes: peak,
        store_bytes: r.store.bytes,
        store_param_bytes: r.store.param_bytes,
        clusters: r.clusters,
    }
}

fn phase_json(tag: &str, p: &RefreshPhase) -> String {
    format!(
        "  \"{tag}\": {{\"n\": {}, \"secs\": {:.4}, \"clients_per_sec\": {:.1}, \
         \"bytes_per_client\": {:.0}, \"peak_live_bytes\": {}, \"store_bytes\": {}, \
         \"store_param_bytes\": {}}}",
        p.n,
        p.secs,
        p.clients_per_sec,
        p.bytes_per_client,
        p.peak_live_bytes,
        p.store_bytes,
        p.store_param_bytes
    )
}

/// The streaming-refresh memory benchmark: fused vs materialized at equal
/// fleet size (per-client bytes), then fused at 10x the fleet (peak memory
/// must stay under the materialized small-fleet run's). Writes
/// results/BENCH_refresh.json.
fn bench_refresh_memory() {
    let n_small = if full_scale() { 10_000 } else { 1_000 };
    let n_large = n_small * 10;
    println!("\nstreaming refresh memory (JL engine, {n_small}/{n_large} clients):");

    let materialized = run_refresh_phase(n_small, false, true, false);
    println!(
        "  materialized N{:<6}  {:>8.2}s  {:>9.0} clients/s  {:>12.0} B/client  peak {:>6.1} MiB",
        materialized.n,
        materialized.secs,
        materialized.clients_per_sec,
        materialized.bytes_per_client,
        materialized.peak_live_bytes as f64 / (1 << 20) as f64,
    );
    let fused = run_refresh_phase(n_small, true, true, false);
    println!(
        "  fused        N{:<6}  {:>8.2}s  {:>9.0} clients/s  {:>12.0} B/client  peak {:>6.1} MiB",
        fused.n,
        fused.secs,
        fused.clients_per_sec,
        fused.bytes_per_client,
        fused.peak_live_bytes as f64 / (1 << 20) as f64,
    );
    let fused_large = run_refresh_phase(n_large, true, false, false);
    println!(
        "  fused        N{:<6}  {:>8.2}s  {:>9.0} clients/s  {:>12.0} B/client  peak {:>6.1} MiB (zero-copy store)",
        fused_large.n,
        fused_large.secs,
        fused_large.clients_per_sec,
        fused_large.bytes_per_client,
        fused_large.peak_live_bytes as f64 / (1 << 20) as f64,
    );

    // Int8-quantized store: same fused fleet held compressed. The tentpole
    // acceptance lines: >= 4x fewer resident store bytes per client, and
    // clusters within 0.95 ARI of the exact-f32 fused run.
    let quantized = run_refresh_phase(n_small, true, true, true);
    println!(
        "  quantized    N{:<6}  {:>8.2}s  {:>9.0} clients/s  {:>12.0} B/client  store {:>6.1} KiB (+{} B params)",
        quantized.n,
        quantized.secs,
        quantized.clients_per_sec,
        quantized.bytes_per_client,
        quantized.store_bytes as f64 / 1024.0,
        quantized.store_param_bytes,
    );

    let bytes_reduction = materialized.bytes_per_client / fused.bytes_per_client.max(1.0);
    let peak_ok = fused_large.peak_live_bytes < materialized.peak_live_bytes;
    println!(
        "    -> bytes generated per client: {bytes_reduction:.1}x reduction (target >= 5x); \
         10x-fleet fused peak under materialized peak: {peak_ok}"
    );
    let store_reduction = fused.store_bytes as f64 / quantized.store_bytes.max(1) as f64;
    let quant_ari = stats::adjusted_rand_index(&quantized.clusters, &fused.clusters);
    println!(
        "    -> quantized store: {:.0} -> {:.0} B/client ({store_reduction:.1}x reduction, \
         target >= 4x); clusters ARI vs exact {quant_ari:.3} (target >= 0.95)",
        fused.store_bytes as f64 / fused.n as f64,
        quantized.store_bytes as f64 / quantized.n as f64,
    );

    let json = format!(
        "{{\n{},\n{},\n{},\n{},\n  \"bytes_reduction\": {:.2},\n  \"speedup\": {:.2},\n  \
         \"peak_ok\": {},\n  \"quant_store_reduction\": {:.2},\n  \
         \"quant_ari_vs_exact\": {:.4}\n}}\n",
        phase_json("materialized", &materialized),
        phase_json("fused", &fused),
        phase_json("fused_large", &fused_large),
        phase_json("quantized", &quantized),
        bytes_reduction,
        materialized.secs / fused.secs.max(1e-9),
        peak_ok,
        store_reduction,
        quant_ari,
    );
    std::fs::write("results/BENCH_refresh.json", json)
        .expect("writing results/BENCH_refresh.json");
    println!("\nwrote results/BENCH_refresh.json");
}

fn main() {
    let refresh_only =
        std::env::var("FEDDDE_BENCH_REFRESH_ONLY").map(|v| v == "1").unwrap_or(false);
    println!("table2_summary — per-client summary time (host kernel seconds)\n");
    std::fs::create_dir_all("results").ok();
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    if !refresh_only {
        match Engine::open_default() {
            Ok(_) if Engine::runtime_available() => {
                bench_dataset(&mut b, "femnist");
                bench_dataset(&mut b, "openimage");
            }
            _ => println!(
                "(skipping per-client artifact section: AOT bundle or PJRT backend missing)\n"
            ),
        }
        println!("fleet refresh scaling (pure-Rust JL engine):");
        bench_fleet_refresh(&mut b);
        b.write_tsv("results/table2_summary.tsv").unwrap();
        println!("\nwrote results/table2_summary.tsv");
    }
    bench_refresh_memory();
}
