//! Bench E3 — Table 2 right half: device-clustering time. DBSCAN over
//! P(y) and P(X|y) summaries (HACCS) vs K-means over the proposed encoder
//! summaries, as a function of fleet size N.
//!
//!     cargo bench --bench table2_clustering
//!     FEDDDE_BENCH_FULL=1 cargo bench --bench table2_clustering
//!
//! P(X|y) at OpenImage scale does not fit in memory (the paper's own
//! observation — ">64 GB"); those points are measured at a memory cap and
//! extrapolated with DBSCAN's Theta(N^2 D) law, printed explicitly.

use feddde::cluster::{dbscan, kmeans, minibatch};
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, PxySummary, PySummary, SummaryEngine};
use feddde::util::bench::{full_scale, Bencher};
use feddde::util::mat::{Mat, QuantMat};
use feddde::util::rng::Rng;
use feddde::util::stats;

fn gather(spec: &DatasetSpec, se: &dyn SummaryEngine, engine: &Engine, cap: usize) -> Mat {
    let partition = Partition::build(spec);
    let generator = Generator::new(spec);
    let mut m = Mat::zeros(0, se.dim());
    for part in partition.clients.iter().take(cap) {
        let ds = generator.client_dataset(part, 0);
        let mut rng = Rng::substream(3, &[part.client_id as u64]);
        let (v, _) = se.summarize(engine, &ds, &mut rng).expect("summarize");
        m.push_row(&v);
    }
    m
}

/// Lloyd vs warm-started mini-batch at fleet scale: synthetic group-
/// structured summaries (no artifacts needed), n_clients >= 1000 — the
/// ISSUE-2 acceptance line: mini-batch beats Lloyd's wall clock while
/// keeping ARI within 0.1.
fn bench_minibatch_vs_lloyd(b: &mut Bencher) {
    let sizes: &[usize] = if full_scale() { &[1000, 4000, 16000] } else { &[1000, 4000] };
    for &n in sizes {
        let k = 8usize;
        let d = 128usize;
        // Planted groups: k well-separated Gaussian blobs in d dims.
        let mut rng = Rng::new(3);
        let mut centers = Vec::with_capacity(k);
        for _ in 0..k {
            let c: Vec<f32> = (0..d).map(|_| (rng.normal() * 4.0) as f32).collect();
            centers.push(c);
        }
        let mut pts = Mat::zeros(0, d);
        let mut truth = Vec::with_capacity(n);
        for i in 0..n {
            let g = i % k;
            let row: Vec<f32> = centers[g]
                .iter()
                .map(|&c| c + rng.normal() as f32)
                .collect();
            pts.push_row(&row);
            truth.push(g);
        }

        let mut lcfg = kmeans::KmeansConfig::new(k);
        lcfg.seed = 5;
        let mut lloyd_assign = Vec::new();
        let mut lloyd_skip = 0.0f64;
        let ml = b.bench_once(&format!("lloyd/N{n}xD{d}K{k}"), || {
            // Default Auto pruning engages at this scale — the Table 2
            // Lloyd row rides the bound-pruned kernel end-to-end.
            let r = kmeans::fit(&pts, &lcfg);
            lloyd_skip = r.stats.skip_rate();
            lloyd_assign = r.assignments;
        });

        let mut mcfg = minibatch::MinibatchConfig::new(k);
        mcfg.seed = 5;
        let mut mb_assign = Vec::new();
        let mm = b.bench_once(&format!("minibatch/N{n}xD{d}K{k}"), || {
            mb_assign = minibatch::fit(&pts, &mcfg).assignments;
        });

        // Int8-quantized Lloyd on the same points: the compressed-store
        // clustering path. Quoted as ARI vs the exact f32 fit — the
        // tentpole acceptance line is ARI >= 0.95.
        let qpts = QuantMat::from_mat(&pts);
        let mut qcfg = kmeans::KmeansConfig::new(k);
        qcfg.seed = 5;
        let mut q_assign = Vec::new();
        let mq = b.bench_once(&format!("lloyd_quant/N{n}xD{d}K{k}"), || {
            q_assign = kmeans::fit_quantized(&qpts, &qcfg).assignments;
        });

        let ari_l = stats::adjusted_rand_index(&lloyd_assign, &truth);
        let ari_m = stats::adjusted_rand_index(&mb_assign, &truth);
        let ari_q = stats::adjusted_rand_index(&q_assign, &lloyd_assign);
        println!(
            "    -> N={n}: minibatch {:.2}x faster than Lloyd (ARI {ari_m:.3} vs {ari_l:.3}, \
             delta {:.3}; target: faster at N>=1000, ARI within 0.1); \
             Lloyd bound-pruning skipped {:.0}% of distance computations",
            ml.mean_secs() / mm.mean_secs().max(1e-9),
            ari_l - ari_m,
            lloyd_skip * 100.0
        );
        println!(
            "    -> N={n}: int8 Lloyd {:.2}x vs f32 Lloyd, ARI-vs-exact {ari_q:.3} \
             (target >= 0.95) at {d} B/point instead of {} B",
            ml.mean_secs() / mq.mean_secs().max(1e-9),
            d * 4
        );
    }
}

fn main() {
    println!("table2_clustering — clustering time vs summary family\n");
    let mut b = Bencher::new(std::time::Duration::from_secs(10));
    std::fs::create_dir_all("results").ok();

    println!("mini-batch vs Lloyd at fleet scale (synthetic planted groups):");
    bench_minibatch_vs_lloyd(&mut b);
    println!();

    let engine = match Engine::open_default() {
        Ok(e) if Engine::runtime_available() => e,
        _ => {
            println!("(skipping summary-family section: AOT bundle or PJRT backend missing)");
            b.write_tsv("results/table2_clustering.tsv").unwrap();
            println!("wrote results/table2_clustering.tsv");
            return;
        }
    };

    for name in ["femnist", "openimage"] {
        let preset = DatasetSpec::by_name(name).unwrap();
        let full_n = preset.n_clients;
        let n = if full_scale() { full_n.min(2800) } else { 128 };
        let spec = preset.with_clients(n);

        // P(y): DBSCAN over C-dim label distributions.
        let py = PySummary::new(&spec);
        let m_py = gather(&spec, &py, &engine, n);
        let eps = dbscan::suggest_eps(&m_py, 4, 32) * 1.2;
        let meas = b.bench_once(&format!("{name}/DBSCAN/P(y)/N{n}"), || {
            std::hint::black_box(dbscan::fit(&m_py, &dbscan::DbscanConfig::new(eps.max(1e-6), 4)).n_clusters);
        });
        let extrap = meas.mean_secs() * (full_n as f64 / n as f64).powi(2);
        println!("    -> extrapolated to N={full_n}: {extrap:.1}s (paper: 835.69s OpenImage / 24.5s FEMNIST)");

        // P(X|y): DBSCAN over huge histograms, memory-capped.
        let pxy = PxySummary::new(&spec);
        let cap = ((1usize << 31) / pxy.summary_bytes()).clamp(8, n);
        let m_pxy = gather(&spec, &pxy, &engine, cap);
        let eps2 = dbscan::suggest_eps(&m_pxy, 4, 16) * 1.2;
        let meas = b.bench_once(&format!("{name}/DBSCAN/P(X|y)/N{cap}(cap)"), || {
            std::hint::black_box(
                dbscan::fit(&m_pxy, &dbscan::DbscanConfig::new(eps2.max(1e-6), 4)).n_clusters,
            );
        });
        let extrap = meas.mean_secs() * (full_n as f64 / cap as f64).powi(2);
        let days = extrap / 86_400.0;
        println!(
            "    -> extrapolated to N={full_n}: {extrap:.0}s ({days:.2} days; paper: >2 days OpenImage / 1866s FEMNIST)"
        );

        // Encoder summaries: K-means (the proposed pipeline).
        let enc = EncoderSummary::new(&spec);
        let m_enc = gather(&spec, &enc, &engine, n);
        let meas = b.bench(&format!("{name}/K-means/Encoder/N{n}"), || {
            let mut cfg = kmeans::KmeansConfig::new(spec.n_groups);
            cfg.seed = 5;
            std::hint::black_box(kmeans::fit(&m_enc, &cfg).inertia);
        });
        let extrap = meas.mean_secs() * full_n as f64 / n as f64;
        println!(
            "    -> extrapolated to N={full_n}: {extrap:.1}s (paper: 477.2s OpenImage / 30s FEMNIST)\n"
        );
    }

    b.write_tsv("results/table2_clustering.tsv").unwrap();
    println!("wrote results/table2_clustering.tsv");
}
