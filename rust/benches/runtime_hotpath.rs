//! Perf-pass bench: request-path latency of every AOT artifact the
//! coordinator executes per round, plus rust-native vs HLO K-means, the new
//! mini-batch K-means hot path, the kernel layer (naive vs GEMM projection,
//! naive vs bound-pruned assignment), and the FedAvg aggregation loop.
//! EXPERIMENTS.md §Perf quotes these lines; the kernel section also emits
//! `results/BENCH_kernels.json` with speedups + distance-skip stats.
//!
//!     cargo bench --bench runtime_hotpath
//!
//! Artifact sections need the AOT bundle + a real PJRT backend; the
//! server-side hot loops (K-means, mini-batch, kernels, FedAvg) run
//! everywhere.

use feddde::cluster::{kmeans, minibatch, Pruning};
use feddde::coordinator::fedavg::fedavg;
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::{lit_f32, lit_scalar, to_vec_f32, Engine};
use feddde::util::bench::{Bencher, Measurement};
use feddde::util::mat::{gemm_nt, gemm_nt_f64_serial, Mat, QuantMat};
use feddde::util::rng::Rng;
use feddde::util::stats;

fn bench_artifacts(b: &mut Bencher, engine: &Engine) -> Vec<f32> {
    // --- femnist train step (the most-called artifact in training) ---------
    let spec = DatasetSpec::femnist();
    let params = to_vec_f32(&engine.exec("femnist_init", &[]).unwrap()[0]).unwrap();
    let bsz = spec.train_batch;
    let f = spec.flat_dim();
    let c = spec.classes;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..bsz * f).map(|_| rng.f32()).collect();
    let mut oh = vec![0.0f32; bsz * c];
    for i in 0..bsz {
        oh[i * c + (i % c)] = 1.0;
    }
    engine.warmup(&["femnist_train_B32", "femnist_eval_B512"]).unwrap();
    b.bench("artifact/femnist_train_B32", || {
        let ins = [
            lit_f32(&params, &[params.len()]).unwrap(),
            lit_f32(&x, &[bsz, f]).unwrap(),
            lit_f32(&oh, &[bsz, c]).unwrap(),
            lit_scalar(0.1),
        ];
        std::hint::black_box(engine.exec("femnist_train_B32", &ins).unwrap().len());
    });

    // --- eval ----------------------------------------------------------------
    let be = spec.eval_batch;
    let xe: Vec<f32> = (0..be * f).map(|_| rng.f32()).collect();
    let mut ohe = vec![0.0f32; be * c];
    for i in 0..be {
        ohe[i * c + (i % c)] = 1.0;
    }
    b.bench("artifact/femnist_eval_B512", || {
        let ins = [
            lit_f32(&params, &[params.len()]).unwrap(),
            lit_f32(&xe, &[be, f]).unwrap(),
            lit_f32(&ohe, &[be, c]).unwrap(),
        ];
        std::hint::black_box(engine.exec("femnist_eval_B512", &ins).unwrap().len());
    });

    // --- proposed summary artifact -------------------------------------------
    let part = Partition::build(&spec.clone().with_clients(4));
    let generator = Generator::new(&spec);
    let ds = generator.client_dataset(&part.clients[0], 0);
    let se = feddde::summary::EncoderSummary::new(&spec);
    use feddde::summary::SummaryEngine;
    let mut rng2 = Rng::new(2);
    b.bench("artifact/femnist_summary_k128", || {
        let (v, _) = se.summarize(engine, &ds, &mut rng2).unwrap();
        std::hint::black_box(v.len());
    });

    params
}

/// Kernel-layer section: measures the two GEMM-ified hot paths against
/// their naive baselines and returns the BENCH_kernels.json payload.
fn bench_kernels(b: &mut Bencher) -> String {
    // Projection shape: coreset_k images of flat_dim pixels onto h basis
    // rows — the per-client work in summary::projection. The workload is
    // the shared fixture overhead_report also measures.
    let (ck, fd, h) = feddde::util::bench::PROJECTION_WORKLOAD_SHAPE;
    let (imgs, basis) = feddde::util::bench::projection_workload();
    let m_proj_naive = b.bench(&format!("kernels/projection_naive_{ck}x{fd}x{h}"), || {
        // The pre-kernel-layer path: one scalar f64 GEMV per image
        // (shared baseline, see util::mat::gemm_nt_f64_serial).
        std::hint::black_box(gemm_nt_f64_serial(&imgs, &basis).data()[0]);
    });
    let m_proj_gemm = b.bench(&format!("kernels/projection_gemm_{ck}x{fd}x{h}"), || {
        std::hint::black_box(gemm_nt(&imgs, &basis).data()[0]);
    });

    // Clustered workload at the acceptance scale (N >= 1000, k >= 16):
    // summary vectors cluster by construction, so blobs are the
    // representative geometry for the bounds.
    let (n, d, k) = (2048usize, 64usize, 16usize);
    let mut rng = Rng::new(7);
    let centers: Vec<f32> = (0..k * d).map(|_| (rng.normal() * 8.0) as f32).collect();
    let mut data = Vec::with_capacity(n * d);
    for i in 0..n {
        let c = i % k;
        for j in 0..d {
            data.push(centers[c * d + j] + (rng.normal() * 0.5) as f32);
        }
    }
    let pts = Mat::from_vec(data, n, d);
    let threads = feddde::util::parallel::default_threads();

    let fit_cfg = |pruning: Pruning| {
        let mut cfg = kmeans::KmeansConfig::new(k);
        cfg.seed = 8;
        cfg.threads = threads;
        cfg.pruning = pruning;
        cfg
    };
    // Converged centroids + warm hints: the steady-state Lloyd round.
    let fitted = kmeans::fit(&pts, &fit_cfg(Pruning::Off));
    let hints = fitted.assignments.clone();
    let m_assign_naive = b.bench(&format!("kernels/assign_naive_{n}x{d}x{k}"), || {
        std::hint::black_box(kmeans::assign(&pts, &fitted.centroids, threads).1);
    });
    let mut assign_stats = kmeans::AssignStats::default();
    let m_assign_pruned = b.bench(&format!("kernels/assign_pruned_{n}x{d}x{k}"), || {
        let (_, inertia, st) =
            kmeans::assign_pruned(&pts, &fitted.centroids, threads, Some(&hints));
        assign_stats = st;
        std::hint::black_box(inertia);
    });

    let m_fit_naive = b.bench_once(&format!("kernels/lloyd_fit_naive_{n}x{d}x{k}"), || {
        std::hint::black_box(kmeans::fit(&pts, &fit_cfg(Pruning::Off)).inertia);
    });
    let mut fit_stats = kmeans::AssignStats::default();
    let mut fit_iters = 0usize;
    let m_fit_pruned = b.bench_once(&format!("kernels/lloyd_fit_pruned_{n}x{d}x{k}"), || {
        let r = kmeans::fit(&pts, &fit_cfg(Pruning::Bounds));
        fit_stats = r.stats;
        fit_iters = r.iters;
        std::hint::black_box(r.inertia);
    });
    // Int8-quantized assignment: compressed codes + dequant-free norm
    // screen against the same converged centroids. Approximate (quoted as
    // ARI vs the exact f32 assignment) at 1/4 the point bytes.
    let qpts = QuantMat::from_mat(&pts);
    let exact_assign = kmeans::assign(&pts, &fitted.centroids, threads).0;
    let mut quant_stats = kmeans::AssignStats::default();
    let mut quant_assign: Vec<usize> = Vec::new();
    let m_assign_quant = b.bench(&format!("kernels/assign_quant_{n}x{d}x{k}"), || {
        let (a, inertia, st) =
            kmeans::assign_quantized(&qpts, &fitted.centroids, threads, Some(&hints));
        quant_stats = st;
        quant_assign = a;
        std::hint::black_box(inertia);
    });
    let quant_ari = stats::adjusted_rand_index(&quant_assign, &exact_assign);

    println!(
        "kernels: projection speedup {:.1}x; steady-state assign speedup {:.1}x \
         (skip {:.1}%); Lloyd fit speedup {:.1}x over {} iters (skip {:.1}%); \
         quantized assign {:.1}x vs naive (skip {:.1}%, ARI {:.4}, {}B/point vs {}B)",
        speedup(&m_proj_naive, &m_proj_gemm),
        speedup(&m_assign_naive, &m_assign_pruned),
        assign_stats.skip_rate() * 100.0,
        speedup(&m_fit_naive, &m_fit_pruned),
        fit_iters,
        fit_stats.skip_rate() * 100.0,
        speedup(&m_assign_naive, &m_assign_quant),
        quant_stats.skip_rate() * 100.0,
        quant_ari,
        d,
        d * 4,
    );

    format!(
        "{{\n  \"projection\": {{\"m\": {ck}, \"f\": {fd}, \"h\": {h}, \
         \"naive_s\": {:.6e}, \"gemm_s\": {:.6e}, \"speedup\": {:.2}}},\n  \
         \"assign\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \
         \"naive_s\": {:.6e}, \"pruned_s\": {:.6e}, \"speedup\": {:.2}, \
         \"skip_rate\": {:.4}, \"exact_evals\": {}, \"pairs\": {}}},\n  \
         \"lloyd_fit\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \"iters\": {fit_iters}, \
         \"naive_s\": {:.6e}, \"pruned_s\": {:.6e}, \"speedup\": {:.2}, \
         \"skip_rate\": {:.4}, \"exact_evals\": {}, \"screened\": {}, \"pairs\": {}}},\n  \
         \"assign_quantized\": {{\"n\": {n}, \"d\": {d}, \"k\": {k}, \
         \"naive_s\": {:.6e}, \"quant_s\": {:.6e}, \"speedup\": {:.2}, \
         \"skip_rate\": {:.4}, \"ari_vs_exact\": {:.4}, \
         \"point_bytes\": {d}, \"f32_point_bytes\": {}}}\n}}\n",
        m_proj_naive.mean_secs(),
        m_proj_gemm.mean_secs(),
        speedup(&m_proj_naive, &m_proj_gemm),
        m_assign_naive.mean_secs(),
        m_assign_pruned.mean_secs(),
        speedup(&m_assign_naive, &m_assign_pruned),
        assign_stats.skip_rate(),
        assign_stats.exact,
        assign_stats.pairs,
        m_fit_naive.mean_secs(),
        m_fit_pruned.mean_secs(),
        speedup(&m_fit_naive, &m_fit_pruned),
        fit_stats.skip_rate(),
        fit_stats.exact,
        fit_stats.screened,
        fit_stats.pairs,
        m_assign_naive.mean_secs(),
        m_assign_quant.mean_secs(),
        speedup(&m_assign_naive, &m_assign_quant),
        quant_stats.skip_rate(),
        quant_ari,
        d * 4,
    )
}

fn speedup(naive: &Measurement, fast: &Measurement) -> f64 {
    naive.mean_secs() / fast.mean_secs().max(1e-12)
}

fn main() {
    println!("runtime_hotpath — per-call artifact latency + server-side hot loops\n");
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    std::fs::create_dir_all("results").ok();

    let engine = match Engine::open_default() {
        Ok(e) if Engine::runtime_available() => Some(e),
        _ => {
            println!("(skipping artifact benches: AOT bundle or PJRT backend missing)");
            None
        }
    };
    let spec = DatasetSpec::femnist();
    let params = match &engine {
        Some(e) => bench_artifacts(&mut b, e),
        // Same parameter-vector size the femnist init artifact returns
        // (784*256+256 + 256*128+128 + 128*62+62), so the FedAvg bench below
        // measures the identical workload.
        None => vec![0.05f32; 241_854],
    };

    // --- K-means: rust-native Lloyd assignment vs the HLO kmeans_step --------
    let m_rows = 2816usize;
    let d = spec.summary_dim();
    let k = 8usize;
    let mut rng = Rng::new(4);
    let mut pts = Vec::with_capacity(m_rows * d);
    for _ in 0..m_rows * d {
        pts.push(rng.f32());
    }
    let mat = Mat::from_vec(pts.clone(), m_rows, d);
    b.bench("kmeans/rust_assign_2816x4030", || {
        let cents = Mat::from_vec(pts[..k * d].to_vec(), k, d);
        std::hint::black_box(
            kmeans::assign(&mat, &cents, feddde::util::parallel::default_threads()).1,
        );
    });
    if let Some(engine) = &engine {
        engine.warmup(&["femnist_kmeans_M2816K8"]).unwrap();
        b.bench("kmeans/hlo_step_2816x4030", || {
            let ins = [
                lit_f32(&pts, &[m_rows, d]).unwrap(),
                lit_f32(&pts[..k * d], &[k, d]).unwrap(),
            ];
            std::hint::black_box(engine.exec("femnist_kmeans_M2816K8", &ins).unwrap().len());
        });
    }

    // --- mini-batch K-means: the fleet-scale clustering hot path -------------
    b.bench("kmeans/minibatch_fit_2816x4030", || {
        let mut cfg = minibatch::MinibatchConfig::new(k);
        cfg.seed = 5;
        cfg.max_iters = 30;
        std::hint::black_box(minibatch::fit(&mat, &cfg).inertia);
    });

    // --- kernel layer: naive vs GEMM projection, naive vs pruned assign ------
    // Runs in every environment (no artifacts needed) and always writes
    // results/BENCH_kernels.json; artifact sections above keep their gating.
    let kernels = bench_kernels(&mut b);
    std::fs::write("results/BENCH_kernels.json", &kernels)
        .expect("writing results/BENCH_kernels.json");
    println!("\nwrote results/BENCH_kernels.json");

    // --- FedAvg over 10 updates of femnist params -----------------------------
    let updates: Vec<(Vec<f32>, f64)> =
        (0..10).map(|i| (params.clone(), (i + 1) as f64)).collect();
    b.bench("server/fedavg_10x240k", || {
        std::hint::black_box(fedavg(&updates).unwrap()[0]);
    });

    b.write_tsv("results/runtime_hotpath.tsv").unwrap();
    println!("\nwrote results/runtime_hotpath.tsv");
}
