//! Perf-pass bench: request-path latency of every AOT artifact the
//! coordinator executes per round, plus rust-native vs HLO K-means, the new
//! mini-batch K-means hot path, and the FedAvg aggregation loop.
//! EXPERIMENTS.md §Perf quotes these lines.
//!
//!     cargo bench --bench runtime_hotpath
//!
//! Artifact sections need the AOT bundle + a real PJRT backend; the
//! server-side hot loops (K-means, mini-batch, FedAvg) run everywhere.

use feddde::cluster::{kmeans, minibatch};
use feddde::coordinator::fedavg::fedavg;
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::{lit_f32, lit_scalar, to_vec_f32, Engine};
use feddde::util::bench::Bencher;
use feddde::util::mat::Mat;
use feddde::util::rng::Rng;

fn bench_artifacts(b: &mut Bencher, engine: &Engine) -> Vec<f32> {
    // --- femnist train step (the most-called artifact in training) ---------
    let spec = DatasetSpec::femnist();
    let params = to_vec_f32(&engine.exec("femnist_init", &[]).unwrap()[0]).unwrap();
    let bsz = spec.train_batch;
    let f = spec.flat_dim();
    let c = spec.classes;
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..bsz * f).map(|_| rng.f32()).collect();
    let mut oh = vec![0.0f32; bsz * c];
    for i in 0..bsz {
        oh[i * c + (i % c)] = 1.0;
    }
    engine.warmup(&["femnist_train_B32", "femnist_eval_B512"]).unwrap();
    b.bench("artifact/femnist_train_B32", || {
        let ins = [
            lit_f32(&params, &[params.len()]).unwrap(),
            lit_f32(&x, &[bsz, f]).unwrap(),
            lit_f32(&oh, &[bsz, c]).unwrap(),
            lit_scalar(0.1),
        ];
        std::hint::black_box(engine.exec("femnist_train_B32", &ins).unwrap().len());
    });

    // --- eval ----------------------------------------------------------------
    let be = spec.eval_batch;
    let xe: Vec<f32> = (0..be * f).map(|_| rng.f32()).collect();
    let mut ohe = vec![0.0f32; be * c];
    for i in 0..be {
        ohe[i * c + (i % c)] = 1.0;
    }
    b.bench("artifact/femnist_eval_B512", || {
        let ins = [
            lit_f32(&params, &[params.len()]).unwrap(),
            lit_f32(&xe, &[be, f]).unwrap(),
            lit_f32(&ohe, &[be, c]).unwrap(),
        ];
        std::hint::black_box(engine.exec("femnist_eval_B512", &ins).unwrap().len());
    });

    // --- proposed summary artifact -------------------------------------------
    let part = Partition::build(&spec.clone().with_clients(4));
    let generator = Generator::new(&spec);
    let ds = generator.client_dataset(&part.clients[0], 0);
    let se = feddde::summary::EncoderSummary::new(&spec);
    use feddde::summary::SummaryEngine;
    let mut rng2 = Rng::new(2);
    b.bench("artifact/femnist_summary_k128", || {
        let (v, _) = se.summarize(engine, &ds, &mut rng2).unwrap();
        std::hint::black_box(v.len());
    });

    params
}

fn main() {
    println!("runtime_hotpath — per-call artifact latency + server-side hot loops\n");
    let mut b = Bencher::new(std::time::Duration::from_secs(3));
    std::fs::create_dir_all("results").ok();

    let engine = match Engine::open_default() {
        Ok(e) if Engine::runtime_available() => Some(e),
        _ => {
            println!("(skipping artifact benches: AOT bundle or PJRT backend missing)");
            None
        }
    };
    let spec = DatasetSpec::femnist();
    let params = match &engine {
        Some(e) => bench_artifacts(&mut b, e),
        // Same parameter-vector size the femnist init artifact returns
        // (784*256+256 + 256*128+128 + 128*62+62), so the FedAvg bench below
        // measures the identical workload.
        None => vec![0.05f32; 241_854],
    };

    // --- K-means: rust-native Lloyd assignment vs the HLO kmeans_step --------
    let m_rows = 2816usize;
    let d = spec.summary_dim();
    let k = 8usize;
    let mut rng = Rng::new(4);
    let mut pts = Vec::with_capacity(m_rows * d);
    for _ in 0..m_rows * d {
        pts.push(rng.f32());
    }
    let mat = Mat::from_vec(pts.clone(), m_rows, d);
    b.bench("kmeans/rust_assign_2816x4030", || {
        let cents = Mat::from_vec(pts[..k * d].to_vec(), k, d);
        std::hint::black_box(
            kmeans::assign(&mat, &cents, feddde::util::parallel::default_threads()).1,
        );
    });
    if let Some(engine) = &engine {
        engine.warmup(&["femnist_kmeans_M2816K8"]).unwrap();
        b.bench("kmeans/hlo_step_2816x4030", || {
            let ins = [
                lit_f32(&pts, &[m_rows, d]).unwrap(),
                lit_f32(&pts[..k * d], &[k, d]).unwrap(),
            ];
            std::hint::black_box(engine.exec("femnist_kmeans_M2816K8", &ins).unwrap().len());
        });
    }

    // --- mini-batch K-means: the fleet-scale clustering hot path -------------
    b.bench("kmeans/minibatch_fit_2816x4030", || {
        let mut cfg = minibatch::MinibatchConfig::new(k);
        cfg.seed = 5;
        cfg.max_iters = 30;
        std::hint::black_box(minibatch::fit(&mat, &cfg).inertia);
    });

    // --- FedAvg over 10 updates of femnist params -----------------------------
    let updates: Vec<(Vec<f32>, f64)> =
        (0..10).map(|i| (params.clone(), (i + 1) as f64)).collect();
    b.bench("server/fedavg_10x240k", || {
        std::hint::black_box(fedavg(&updates).unwrap()[0]);
    });

    b.write_tsv("results/runtime_hotpath.tsv").unwrap();
    println!("\nwrote results/runtime_hotpath.tsv");
}
