//! Bench E5 — convergence / time-to-accuracy across selection policies
//! (the HACCS-inherited claim the summary pipeline serves: cluster-based
//! selection cuts time-to-accuracy vs random without hurting accuracy).
//!
//!     cargo bench --bench convergence
//!     FEDDDE_BENCH_FULL=1 cargo bench --bench convergence

use feddde::config::ExperimentConfig;
use feddde::coordinator::Coordinator;
use feddde::runtime::Engine;
use feddde::util::bench::full_scale;

fn main() {
    let (clients, rounds) = if full_scale() { (300, 200) } else { (80, 50) };
    println!("convergence — femnist-like, {clients} clients, {rounds} rounds, policies compared\n");
    std::fs::create_dir_all("results").ok();
    let mut lines = vec![
        "# policy\tbest_acc\tfinal_acc\tsim_time_total\trounds_to_half\tsim_t_to_half".to_string(),
    ];

    // First pass to find a common target: half of the max best accuracy.
    let mut logs = Vec::new();
    for policy in ["cluster", "random", "round_robin", "oort"] {
        let cfg = ExperimentConfig {
            dataset: "femnist".into(),
            n_clients: clients,
            rounds,
            per_round: 8,
            local_steps: 3,
            lr: 0.1,
            policy: policy.into(),
            seed: 17,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let mut coord = Coordinator::new(cfg, Engine::open_default().expect("artifacts")).unwrap();
        coord.run().unwrap();
        println!(
            "{:<12} best acc {:.4}  final {:.4}  sim_time {:>9.1}s  (wall {:.1}s)",
            policy,
            coord.log.best_accuracy(),
            coord.log.final_accuracy(),
            coord.log.rounds.last().map(|r| r.sim_time).unwrap_or(0.0),
            t0.elapsed().as_secs_f64()
        );
        logs.push((policy, coord.log));
    }

    let target = logs.iter().map(|(_, l)| l.best_accuracy()).fold(f64::INFINITY, f64::min) * 0.9;
    println!("\ntime-to-accuracy at target {target:.3}:");
    for (policy, log) in &logs {
        let (r, t) = match (log.rounds_to_accuracy(target), log.time_to_accuracy(target)) {
            (Some(r), Some(t)) => (r as i64, t),
            _ => (-1, f64::NAN),
        };
        println!("  {policy:<12} round {r:>5}   sim {t:>9.1}s");
        lines.push(format!(
            "{policy}\t{:.4}\t{:.4}\t{:.1}\t{r}\t{t:.1}",
            log.best_accuracy(),
            log.final_accuracy(),
            log.rounds.last().map(|x| x.sim_time).unwrap_or(0.0)
        ));
    }
    let cluster_t = logs.iter().find(|(p, _)| *p == "cluster").and_then(|(_, l)| l.time_to_accuracy(target));
    let random_t = logs.iter().find(|(p, _)| *p == "random").and_then(|(_, l)| l.time_to_accuracy(target));
    if let (Some(c), Some(r)) = (cluster_t, random_t) {
        println!(
            "\ncluster vs random time-to-accuracy: {:+.1}% (HACCS paper: 18-38% reduction)",
            100.0 * (1.0 - c / r)
        );
    }
    std::fs::write("results/convergence.tsv", lines.join("\n") + "\n").unwrap();
    println!("wrote results/convergence.tsv");
}
