//! End-to-end selection-overhead bench (the paper's Table-3-style study,
//! run through the discrete-event fleet simulator): every selection
//! strategy drives full FL rounds — availability, over-selection with
//! deadlines, stragglers, dropouts, FedAvg, drift-triggered incremental
//! refresh — with the coordinator's own summary/clustering time charged on
//! the simulated clock. Emits `results/BENCH_sim.json` with two sections:
//!
//! 1. **Strategy sweep** — all `selection::STRATEGY_NAMES` at N ∈ {100,
//!    1000} clients (plus 10 000 under `FEDDDE_BENCH_FULL=1`) on the
//!    `straggler_cut` scenario: simulated round-time breakdown (refresh /
//!    selection / compute / upload / wait), coverage, and stragglers
//!    dropped, per strategy.
//! 2. **Scenario matrix** — a 50-client × 5-round sweep over the scenario
//!    catalog under the cluster policy (`make sim-smoke`'s payload).
//!    Followed by a shard/lazy probe asserting the sharded tier and lazy
//!    arrival sampling leave the event stream bitwise untouched (the
//!    million-client sweep itself lives in `run-sim --scale`, which emits
//!    `results/BENCH_scale.json`; see `make scale-smoke`).
//! 3. **Chaos matrix** — the fault-injection trio (`regional_outage`,
//!    `flaky_uplink`, `byzantine_summaries`) through the full kill →
//!    recover → resume protocol, with retry/quarantine/degraded-close
//!    counters and the simulated-time overhead versus an identically-sized
//!    `sync_baseline` run — written to `results/BENCH_chaos.json`.
//!
//! Everything is pure Rust (JL summaries, no AOT artifacts needed), so this
//! runs in every environment. Event digests are quoted per run: equal
//! digests across machines/thread counts certify the simulated streams
//! matched bitwise.
//!
//!     cargo bench --bench sim_overhead

use feddde::config::SimConfig;
use feddde::selection::STRATEGY_NAMES;
use feddde::sim::{run_with_recovery, write_bench_json, Scenario, Simulator};
use feddde::util::bench::full_scale;
use feddde::util::cli::{CommandSpec, FlagSpec, Parsed};

const SPEC: CommandSpec = CommandSpec {
    name: "sim_overhead",
    blurb: "end-to-end selection overhead via the fleet simulator",
    flags: &[
        FlagSpec::switch("full", "include the 10k-client scale (same as FEDDDE_BENCH_FULL=1)"),
        FlagSpec::arg("out", "PATH", "aggregate JSON artifact (default results/BENCH_sim.json)"),
        FlagSpec::arg(
            "chaos-out",
            "PATH",
            "chaos-matrix JSON artifact (default results/BENCH_chaos.json)",
        ),
    ],
};

fn run_one(cfg: SimConfig, scenario: &str) -> String {
    let sc = Scenario::by_name(scenario).expect("unknown scenario");
    let t0 = std::time::Instant::now();
    // Crash scenarios charge the full kill → recover → resume protocol to
    // the host clock (recovery overhead is exactly what they benchmark).
    let rep = if sc.crash.is_some() {
        run_with_recovery(cfg, sc).expect("crash/recovery run").report
    } else {
        Simulator::new(cfg, sc)
            .expect("simulator construction")
            .run()
            .expect("simulation run")
    };
    let host = t0.elapsed().as_secs_f64();
    let t = rep.totals();
    println!(
        "{:<14} {:<12} n={:<6} sim {:>10.1}s  refresh {:>8.2}s ({:>4.1}%)  \
         select {:>7.4}s  compute {:>8.1}s  upload {:>6.1}s  cov {:.3}  \
         done/drop/cut {}/{}/{}  [host {:.2}s]",
        rep.scenario,
        rep.policy,
        rep.n_clients,
        t.sim_secs,
        t.refresh_secs,
        100.0 * t.refresh_secs / t.sim_secs.max(1e-12),
        t.selection_secs,
        t.compute_secs,
        t.upload_secs,
        t.coverage,
        t.completed,
        t.dropped,
        t.timed_out,
        host
    );
    rep.bench_entry_json(host)
}

fn main() {
    // Cargo passes through args after `--`; "--bench" also shows up when run
    // via `cargo bench`, so drop non-flag noise before parsing.
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a.starts_with("--") && a != "--bench")
        .collect();
    let flags = Parsed::parse(&SPEC, &args).expect("bench flags");
    if flags.help {
        println!("{}", SPEC.help());
        return;
    }
    let out = flags.get("out").unwrap_or("results/BENCH_sim.json").to_string();
    println!("sim_overhead — end-to-end selection overhead via the fleet simulator\n");
    std::fs::create_dir_all("results").ok();
    let mut entries: Vec<String> = Vec::new();

    // --- Section 1: strategy sweep at scale ---------------------------------
    let mut scales = vec![100usize, 1000];
    if full_scale() || flags.has("full") {
        scales.push(10_000);
    }
    println!("== strategy sweep (scenario straggler_cut) ==");
    for &n in &scales {
        for policy in STRATEGY_NAMES {
            let cfg = SimConfig {
                n_clients: n,
                rounds: 5,
                per_round: (n / 10).clamp(4, 100),
                policy: policy.into(),
                refresh_every: 2,
                seed: 1,
                ..Default::default()
            };
            entries.push(run_one(cfg, "straggler_cut"));
        }
        println!();
    }

    // --- Section 2: scenario matrix (the sim-smoke payload) -----------------
    println!("== scenario matrix (50 clients x 5 rounds, cluster policy) ==");
    for sc in Scenario::NAMES {
        let cfg = SimConfig {
            n_clients: 50,
            rounds: 5,
            per_round: 10,
            refresh_every: 2,
            seed: 2,
            ..Default::default()
        };
        entries.push(run_one(cfg, sc));
    }

    if let Err(e) = write_bench_json(&out, &entries) {
        eprintln!("sim_overhead: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {out} ({} runs)", entries.len());

    // --- Section 2b: shard/lazy scale probe ---------------------------------
    // The sharded tier and lazy arrival sampling must neither change results
    // nor slow the flat path; quote the digests side by side so a regression
    // is visible in the bench log before the determinism suite runs.
    println!("\n== shard & lazy probe (1000 clients x 4 rounds) ==");
    let probe = |shards: usize, lazy: bool, policy: &str| {
        let cfg = SimConfig {
            n_clients: 1000,
            rounds: 4,
            per_round: 50,
            policy: policy.into(),
            refresh_every: 2,
            shards,
            lazy_arrivals: lazy,
            seed: 4,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let rep = Simulator::new(cfg, Scenario::by_name("straggler_cut").unwrap())
            .expect("probe simulator")
            .run()
            .expect("probe run");
        let host = t0.elapsed().as_secs_f64();
        println!(
            "{:<8} shards {:>2} lazy {:<5}  events {:#018x}  peak store {:>9} B  [host {:.2}s]",
            policy,
            shards,
            lazy,
            rep.event_digest(),
            rep.peak_store_bytes,
            host
        );
        rep.event_digest()
    };
    let flat = probe(1, false, "cluster");
    for s in [4, 16] {
        assert_eq!(probe(s, false, "cluster"), flat, "shards={s} diverged the stream");
    }
    let eager = probe(1, false, "random");
    assert_eq!(probe(1, true, "random"), eager, "lazy arrivals diverged the stream");

    // --- Section 3: chaos matrix → BENCH_chaos.json -------------------------
    // Same fleet shape for the baseline and every chaos run, so the
    // overhead_frac in each entry is purely the fault fabric's doing.
    let chaos_out = flags.get("chaos-out").unwrap_or("results/BENCH_chaos.json").to_string();
    println!("\n== chaos matrix (fault injection, 50 clients x 6 rounds) ==");
    let chaos_cfg = || SimConfig {
        n_clients: 50,
        rounds: 6,
        per_round: 10,
        refresh_every: 2,
        seed: 3,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let baseline = Simulator::new(chaos_cfg(), Scenario::by_name("sync_baseline").unwrap())
        .expect("baseline simulator")
        .run()
        .expect("baseline run");
    let baseline_host = t0.elapsed().as_secs_f64();
    let baseline_secs = baseline.totals().sim_secs;
    println!(
        "{:<20} sim {:>9.1}s (reference)  [host {:.2}s]",
        "sync_baseline", baseline_secs, baseline_host
    );
    let mut chaos_entries = vec![baseline.chaos_entry_json(0.0, baseline_host)];
    for name in ["regional_outage", "flaky_uplink", "byzantine_summaries"] {
        let sc = Scenario::by_name(name).expect("unknown chaos scenario");
        let t0 = std::time::Instant::now();
        let rep = run_with_recovery(chaos_cfg(), sc).expect("chaos kill/recover/resume").report;
        let host = t0.elapsed().as_secs_f64();
        let t = rep.totals();
        println!(
            "{:<20} sim {:>9.1}s ({:>+6.1}% vs baseline)  retries {}  failed {}  \
             rejects {}  quarantined {}  degraded {}  [host {:.2}s]",
            name,
            t.sim_secs,
            100.0 * (t.sim_secs / baseline_secs.max(1e-12) - 1.0),
            t.retries,
            t.failed,
            t.summary_rejects,
            t.quarantined,
            t.degraded_rounds,
            host
        );
        chaos_entries.push(rep.chaos_entry_json(baseline_secs, host));
    }
    if let Err(e) = write_bench_json(&chaos_out, &chaos_entries) {
        eprintln!("sim_overhead: {e}");
        std::process::exit(1);
    }
    println!("\nwrote {chaos_out} ({} runs)", chaos_entries.len());
}
