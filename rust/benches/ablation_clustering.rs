//! Bench E8 — clustering ablations:
//!
//!  * DBSCAN eps sensitivity (paper §3: "it can sometimes put all devices
//!    to the same group, and can not return a meaningful clustering
//!    solution") — sweep eps, report cluster count + ARI cliff;
//!  * K-means k sweep — quality is stable around the true group count,
//!    the robustness argument for §4.2.
//!
//!     cargo bench --bench ablation_clustering

use feddde::cluster::{dbscan, kmeans};
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, SummaryEngine};
use feddde::util::mat::Mat;
use feddde::util::rng::Rng;
use feddde::util::stats;

fn main() {
    println!("ablation_clustering — DBSCAN parameter sensitivity vs K-means robustness\n");
    let spec = DatasetSpec::femnist().with_clients(96);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let truth = partition.group_truth();
    let engine = Engine::open_default().expect("artifacts");

    let se = EncoderSummary::new(&spec);
    let mut m = Mat::zeros(0, se.dim());
    for part in &partition.clients {
        let ds = generator.client_dataset(part, 0);
        let mut rng = Rng::substream(1, &[part.client_id as u64]);
        let (v, _) = se.summarize(&engine, &ds, &mut rng).expect("summarize");
        m.push_row(&v);
    }
    let m = feddde::cluster::balance_blocks(&m, &se.blocks());

    std::fs::create_dir_all("results").ok();
    let mut rows = vec!["# algo\tparam\tclusters\tnoise\tari".to_string()];

    let eps0 = dbscan::suggest_eps(&m, 4, 48);
    println!("DBSCAN eps sweep (suggest_eps = {eps0:.4}):");
    println!("{:>10} {:>9} {:>7} {:>7}", "eps", "clusters", "noise", "ARI");
    for mult in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0, 16.0] {
        let eps = eps0 * mult;
        let res = dbscan::fit(&m, &dbscan::DbscanConfig::new(eps, 4));
        let ari = stats::adjusted_rand_index(&res.total_labels(), &truth);
        let note = if res.n_clusters <= 1 && res.n_noise == 0 {
            "  <- all devices in one group (the paper's failure mode)"
        } else if res.n_clusters == 0 {
            "  <- everything noise"
        } else {
            ""
        };
        println!("{:>10.4} {:>9} {:>7} {:>7.3}{note}", eps, res.n_clusters, res.n_noise, ari);
        rows.push(format!("dbscan\t{eps:.5}\t{}\t{}\t{ari:.4}", res.n_clusters, res.n_noise));
    }

    println!("\nK-means k sweep (true groups = {}):", spec.n_groups);
    println!("{:>10} {:>9} {:>7}", "k", "clusters", "ARI");
    for k in [2usize, 4, 6, 8, 10, 12, 16] {
        let mut cfg = kmeans::KmeansConfig::new(k);
        cfg.seed = 7;
        let res = kmeans::fit(&m, &cfg);
        let ari = stats::adjusted_rand_index(&res.assignments, &truth);
        println!("{k:>10} {:>9} {ari:>7.3}", k);
        rows.push(format!("kmeans\t{k}\t{k}\t0\t{ari:.4}"));
    }

    std::fs::write("results/ablation_clustering.tsv", rows.join("\n") + "\n").unwrap();
    println!("\nwrote results/ablation_clustering.tsv");
}
