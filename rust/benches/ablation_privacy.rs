//! Bench E9 — privacy/utility trade-off (paper §5: DP is complementary to
//! the proposed summaries): sweep the local-DP epsilon applied on-device to
//! each summary and measure downstream clustering quality (ARI) plus the
//! composed budget over periodic refreshes.
//!
//!     cargo bench --bench ablation_privacy

use feddde::cluster::kmeans;
use feddde::data::{DatasetSpec, Generator, Partition};
use feddde::privacy::PrivacyAccountant;
use feddde::runtime::Engine;
use feddde::summary::{DpSummary, EncoderSummary, SummaryEngine};
use feddde::util::mat::Mat;
use feddde::util::rng::Rng;
use feddde::util::stats;

fn fleet_ari(se: &dyn SummaryEngine, engine: &Engine, partition: &Partition, generator: &Generator, k: usize) -> f64 {
    let mut m = Mat::zeros(0, se.dim());
    for part in &partition.clients {
        let ds = generator.client_dataset(part, 0);
        let mut rng = Rng::substream(21, &[part.client_id as u64]);
        let (v, _) = se.summarize(engine, &ds, &mut rng).expect("summarize");
        m.push_row(&v);
    }
    let balanced = feddde::cluster::balance_blocks(&m, &se.blocks());
    let mut cfg = kmeans::KmeansConfig::new(k);
    cfg.seed = 5;
    stats::adjusted_rand_index(&kmeans::fit(&balanced, &cfg).assignments, &partition.group_truth())
}

fn main() {
    println!("ablation_privacy — local-DP epsilon vs clustering quality\n");
    let spec = DatasetSpec::femnist().with_clients(72);
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let engine = Engine::open_default().expect("artifacts");
    std::fs::create_dir_all("results").ok();
    let mut rows = vec!["# epsilon\tari".to_string()];

    let clean = fleet_ari(&EncoderSummary::new(&spec), &engine, &partition, &generator, spec.n_groups);
    println!("{:>10} {:>7}", "epsilon", "ARI");
    println!("{:>10} {:>7.3}   (no DP)", "inf", clean);
    rows.push(format!("inf\t{clean:.4}"));

    for eps in [10.0, 3.0, 1.0, 0.3, 0.1] {
        let se = DpSummary::new(Box::new(EncoderSummary::new(&spec)), eps, 1e-5);
        let ari = fleet_ari(&se, &engine, &partition, &generator, spec.n_groups);
        println!("{eps:>10} {ari:>7.3}");
        rows.push(format!("{eps}\t{ari:.4}"));
    }

    // Budget composition over periodic refreshes (refresh_every=10, 100 rounds
    // -> 10 releases): what per-release epsilon keeps the total under 8?
    println!("\ncomposed budget over 10 refreshes (advanced composition, delta'=1e-6):");
    for eps in [1.0, 0.5, 0.25] {
        let mut acc = PrivacyAccountant::new(eps, 1e-5, 0.0);
        for _ in 0..10 {
            acc.record_release();
        }
        println!(
            "  eps/release {eps:<5} -> basic {:.2}, advanced {:.2}",
            acc.basic_epsilon(),
            acc.advanced_epsilon(1e-6)
        );
    }
    std::fs::write("results/ablation_privacy.tsv", rows.join("\n") + "\n").unwrap();
    println!("\nwrote results/ablation_privacy.tsv");
}
