//! Minimal offline stand-in for the `anyhow` crate, covering exactly the
//! surface this repository uses: `Result`, `Error`, the `Context` extension
//! trait on `Result` and `Option`, and the `bail!` / `anyhow!` macros.
//!
//! Semantics match the real crate where it matters to callers here:
//! `Display` shows the outermost message; `{:#}` (alternate) joins the whole
//! context chain with `": "`, outermost first — test assertions like
//! `format!("{err:#}").contains(...)` behave identically.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `.unwrap()` on an Err prints this; mirror anyhow's Debug layout
        // (message, then the context chain as causes).
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e.to_string())
    }
}

/// `anyhow::Result<T>` alias with our Error as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        // `{:#}` so an inner `anyhow::Error` keeps its full chain.
        self.map_err(|e| Error::msg(format!("{e:#}")).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted `Err(anyhow::Error)`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Construct an `anyhow::Error` from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn display_and_alternate_chain() {
        let err = fails().context("outer").unwrap_err();
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:#}"), "outer: root 42");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let err = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{err:#}"), "missing x");
    }

    #[test]
    fn std_error_converts() {
        let e: Error = "nan".parse::<f64>().unwrap_err().into();
        assert!(!format!("{e}").is_empty());
    }
}
