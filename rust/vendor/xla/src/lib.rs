//! Offline **stub** of the XLA/PJRT binding the runtime layer targets.
//!
//! `Literal` is fully functional (typed storage + shape + reshape +
//! element access) so the literal-helper code paths and their tests run for
//! real. The PJRT half — HLO parsing, compilation, execution — returns
//! errors: there is no XLA runtime in this environment. `feddde::runtime`
//! gates everything artifact-dependent on [`runtime_available`], which a real
//! binding's shim should override to `true` (see vendor/README.md).

use std::fmt;

/// True when a real PJRT backend is linked. This stub has none.
pub fn runtime_available() -> bool {
    false
}

/// Stub error type.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT runtime unavailable (vendored xla stub — swap in a real \
         binding per rust/vendor/README.md)"
    ))
}

// ---------------------------------------------------------------------------
// Literal: functional
// ---------------------------------------------------------------------------

/// Element types a literal can hold.
#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host tensor: typed flat storage plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Conversion trait for typed element access (implemented for f32 and i32).
pub trait NativeType: Sized + Copy {
    fn extract(lit: &Literal) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn extract(lit: &Literal) -> Option<&[f32]> {
        match &lit.data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn extract(lit: &Literal) -> Option<&[i32]> {
        match &lit.data {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Scalar f32 literal (shape `[]`).
    pub fn scalar(v: f32) -> Literal {
        Literal { data: Data::F32(vec![v]), dims: Vec::new() }
    }

    /// Rank-1 f32 literal.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: Data::F32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// Rank-1 i32 literal.
    pub fn vec1_i32(data: &[i32]) -> Literal {
        Literal { data: Data::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    /// A tuple literal (what executions return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(elements), dims: Vec::new() }
    }

    /// Reinterpret with new dimensions; errors if the element count differs.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(XlaError(format!(
                "reshape: {} elements into shape {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    /// All elements as `T` (errors on dtype mismatch or tuple).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
            .map(|s| s.to_vec())
            .ok_or_else(|| XlaError("to_vec: literal dtype mismatch".into()))
    }

    /// First element as `T`.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::extract(self)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| XlaError("get_first_element: empty or dtype mismatch".into()))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Data::Tuple(t) => Ok(t.clone()),
            _ => Err(XlaError("to_tuple: literal is not a tuple".into())),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

// ---------------------------------------------------------------------------
// PJRT: stubbed
// ---------------------------------------------------------------------------

/// Parsed HLO module (stub: never constructible from text here).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A PJRT client handle. Creation succeeds (cheap, lets manifest-free
/// engines exist for pure-Rust summary paths); compilation does not.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling"))
    }
}

/// A compiled executable (stub: never constructible).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// A device buffer (stub: never constructible).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.shape(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7.5);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 7.5);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1_i32(&[1, 2])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].to_vec::<i32>().unwrap(), vec![1, 2]);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_stubbed() {
        assert!(!runtime_available());
        assert!(PjRtClient::cpu().is_ok());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
