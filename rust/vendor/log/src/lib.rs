//! Minimal offline stand-in for the `log` crate: the five level macros,
//! printing to stderr when `RUST_LOG` is set and doing nothing otherwise.

/// True when logging output is enabled (any non-empty `RUST_LOG`).
pub fn enabled() -> bool {
    std::env::var_os("RUST_LOG").map(|v| !v.is_empty()).unwrap_or(false)
}

#[doc(hidden)]
pub fn emit(level: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{level}] {args}");
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        if $crate::enabled() { $crate::emit("TRACE", format_args!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::enabled() { $crate::emit("DEBUG", format_args!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::enabled() { $crate::emit("INFO", format_args!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        if $crate::enabled() { $crate::emit("WARN", format_args!($($arg)*)); }
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        if $crate::enabled() { $crate::emit("ERROR", format_args!($($arg)*)); }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_compile_and_run() {
        // No assertion on output — just exercise every macro's expansion.
        trace!("t {}", 1);
        debug!("d {}", 2);
        info!("i {}", 3);
        warn!("w {}", 4);
        error!("e {}", 5);
    }
}
