//! Integration tests: the full pipeline across modules, always on the
//! seconds-scale `tiny` artifacts. Skipped gracefully (early return) when
//! `artifacts/` has not been built — `make test` always builds it first.

use feddde::cluster::{dbscan, kmeans};
use feddde::config::ExperimentConfig;
use feddde::coordinator::{refresh_fleet, Coordinator};
use feddde::data::{DatasetSpec, DriftSchedule, Generator, Partition};
use feddde::device::FleetModel;
use feddde::runtime::Engine;
use feddde::summary::{EncoderSummary, PxySummary, PySummary, SummaryEngine};
use feddde::util::rng::Rng;
use feddde::util::stats;

fn engine() -> Option<Engine> {
    // Prints an explicit SKIP line when the AOT bundle or a real PJRT
    // backend is missing, so green runs can't silently mean "nothing ran".
    feddde::runtime::test_engine()
}

#[test]
fn summary_to_clustering_pipeline_recovers_groups() {
    let Some(eng) = engine() else { return };
    let spec = DatasetSpec::tiny();
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let fleet = FleetModel::default().sample_fleet(spec.n_clients);
    let se = EncoderSummary::new(&spec);
    let r = refresh_fleet(
        &eng,
        &se,
        &partition,
        &generator,
        &fleet,
        &DriftSchedule::none(),
        0,
        spec.n_groups,
        1,
    )
    .unwrap();
    let ari = stats::adjusted_rand_index(&r.clusters, &partition.group_truth());
    assert!(ari > 0.2, "pipeline ARI too low: {ari}");
    // Summaries are finite and the right shape.
    assert_eq!(r.summaries.rows(), spec.n_clients);
    assert_eq!(r.summaries.cols(), spec.summary_dim());
    for i in 0..r.summaries.rows() {
        assert!(r.summaries.row(i).iter().all(|v| v.is_finite()));
    }
}

#[test]
fn all_three_summary_engines_execute_on_all_tiny_clients() {
    let Some(eng) = engine() else { return };
    let spec = DatasetSpec::tiny();
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let engines: Vec<Box<dyn SummaryEngine>> = vec![
        Box::new(PySummary::new(&spec)),
        Box::new(PxySummary::new(&spec)),
        Box::new(EncoderSummary::new(&spec)),
    ];
    for se in &engines {
        for part in &partition.clients {
            let ds = generator.client_dataset(part, 0);
            let mut rng = Rng::new(part.client_id as u64);
            let (v, secs) = se.summarize(&eng, &ds, &mut rng).unwrap();
            assert_eq!(v.len(), se.dim(), "{} wrong dim", se.name());
            assert!(v.iter().all(|x| x.is_finite()), "{} non-finite", se.name());
            assert!(secs >= 0.0);
        }
    }
}

#[test]
fn proposed_summary_separates_groups_better_than_py_alone() {
    // The paper's qualitative claim: P(y) misses feature-level heterogeneity.
    // Groups in our substrate differ in BOTH label priors and feature
    // transforms, so encoder summaries should cluster at least as well.
    let Some(eng) = engine() else { return };
    let spec = DatasetSpec::tiny();
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let truth = partition.group_truth();

    let ari_of = |se: &dyn SummaryEngine| -> f64 {
        let mut m = feddde::util::mat::Mat::zeros(0, se.dim());
        for part in &partition.clients {
            let ds = generator.client_dataset(part, 0);
            let mut rng = Rng::new(part.client_id as u64);
            m.push_row(&se.summarize(&eng, &ds, &mut rng).unwrap().0);
        }
        let balanced = feddde::cluster::balance_blocks(&m, &se.blocks());
        let mut cfg = kmeans::KmeansConfig::new(spec.n_groups);
        cfg.seed = 3;
        stats::adjusted_rand_index(&kmeans::fit(&balanced, &cfg).assignments, &truth)
    };
    let enc = ari_of(&EncoderSummary::new(&spec));
    let py = ari_of(&PySummary::new(&spec));
    // tiny has only 24 clients, so ARI is high-variance; the margin here is
    // a sanity floor. The femnist-scale comparison lives in
    // benches/ablation_summary.rs where the gap is measured properly.
    assert!(
        enc >= py - 0.25,
        "encoder summary ({enc:.3}) clusters much worse than P(y) ({py:.3})"
    );
}

#[test]
fn end_to_end_training_with_drift_and_refresh() {
    let Some(_) = engine() else { return };
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        rounds: 10,
        per_round: 4,
        local_steps: 2,
        lr: 0.2,
        policy: "cluster".into(),
        refresh_every: 4,
        drift_rounds: vec![5],
        drift_frac: 1.0,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, Engine::open_default().unwrap()).unwrap();
    let log = coord.run().unwrap();
    assert_eq!(log.rounds.len(), 10);
    assert!(log.rounds.iter().all(|r| r.train_loss.is_finite()));
    // Training still works after the drift round.
    let post = &log.rounds[9];
    assert!(post.eval_accuracy >= 0.0 && post.eval_accuracy <= 1.0);
}

#[test]
fn target_accuracy_stops_early() {
    let Some(_) = engine() else { return };
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        rounds: 100,
        per_round: 6,
        local_steps: 4,
        lr: 0.3,
        policy: "random".into(),
        target_accuracy: 0.5, // tiny converges fast past 0.5
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, Engine::open_default().unwrap()).unwrap();
    let log = coord.run().unwrap();
    assert!(
        log.rounds.len() < 100,
        "early stop never triggered ({} rounds, best {:.3})",
        log.rounds.len(),
        log.best_accuracy()
    );
}

#[test]
fn hlo_kmeans_step_agrees_with_rust_kmeans_assignment() {
    // The L1 Pallas distance kernel (via the tiny_kmeans artifact) and the
    // rust-native assignment must agree on which centroid each point gets.
    let Some(eng) = engine() else { return };
    let m = 64usize;
    let d = DatasetSpec::tiny().summary_dim();
    let k = 3usize;
    let mut rng = Rng::new(5);
    let pts: Vec<f32> = (0..m * d).map(|_| rng.f32()).collect();
    let cents: Vec<f32> = pts[..k * d].to_vec();

    let ins = [
        feddde::runtime::lit_f32(&pts, &[m, d]).unwrap(),
        feddde::runtime::lit_f32(&cents, &[k, d]).unwrap(),
    ];
    let outs = eng.exec("tiny_kmeans_M64K3", &ins).unwrap();
    let hlo_assign = feddde::runtime::to_vec_i32(&outs[1]).unwrap();

    let mat = feddde::util::mat::Mat::from_vec(pts, m, d);
    let cmat = feddde::util::mat::Mat::from_vec(cents, k, d);
    let (rust_assign, _) = kmeans::assign(&mat, &cmat, 2);
    for i in 0..m {
        assert_eq!(
            hlo_assign[i] as usize, rust_assign[i],
            "assignment mismatch at point {i}"
        );
    }
}

#[test]
fn dbscan_over_pxy_summaries_runs() {
    // The full HACCS baseline path: P(X|y) histograms -> DBSCAN.
    let Some(eng) = engine() else { return };
    let spec = DatasetSpec::tiny();
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let se = PxySummary::new(&spec);
    let mut m = feddde::util::mat::Mat::zeros(0, se.dim());
    for part in &partition.clients {
        let ds = generator.client_dataset(part, 0);
        let mut rng = Rng::new(part.client_id as u64);
        m.push_row(&se.summarize(&eng, &ds, &mut rng).unwrap().0);
    }
    let eps = dbscan::suggest_eps(&m, 3, 16);
    let res = dbscan::fit(&m, &dbscan::DbscanConfig::new(eps * 1.5, 3));
    assert_eq!(res.labels.len(), spec.n_clients);
}

#[test]
fn metrics_files_are_written() {
    let Some(_) = engine() else { return };
    let dir = std::env::temp_dir().join("feddde_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("run.jsonl");
    let cfg = ExperimentConfig {
        dataset: "tiny".into(),
        rounds: 3,
        per_round: 3,
        local_steps: 1,
        ..Default::default()
    };
    let mut coord = Coordinator::new(cfg, Engine::open_default().unwrap()).unwrap();
    coord.run().unwrap();
    coord.log.write_jsonl(out.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert_eq!(text.lines().count(), 3);
    assert!(text.contains("\"eval_accuracy\""));
}

// ---------------------------------------------------------------------------
// Fleet simulator end-to-end (pure Rust — never skipped): at least three
// named scenarios run full rounds, produce per-round wall-clock breakdowns,
// and write well-formed JSONL + BENCH_sim.json-shaped aggregates.

#[test]
fn run_sim_executes_named_scenarios_end_to_end() {
    use feddde::config::SimConfig;
    use feddde::sim::{bench_json, Scenario, Simulator};

    let dir = std::env::temp_dir().join("feddde_sim_it");
    std::fs::create_dir_all(&dir).unwrap();
    let mut entries = Vec::new();
    for name in ["sync_baseline", "heavy_tail", "drift_burst", "partial_async"] {
        let cfg = SimConfig {
            n_clients: 40,
            rounds: 5,
            per_round: 8,
            refresh_every: 2,
            seed: 5,
            ..Default::default()
        };
        let rep = Simulator::new(cfg, Scenario::by_name(name).unwrap())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(rep.rounds.len(), 5, "{name}");
        let t = rep.totals();
        assert!(t.sim_secs > 0.0, "{name}: no simulated time elapsed");
        assert!(t.completed > 0, "{name}: nothing ever completed");
        assert!(
            t.refresh_secs > 0.0,
            "{name}: cluster policy must pay refresh overhead"
        );
        assert!(t.selection_secs > 0.0, "{name}");
        assert!(t.coverage > 0.0 && t.coverage <= 1.0, "{name}");
        // Per-round breakdown components are non-negative and sum to the
        // round's wall clock.
        for r in &rep.rounds {
            for part in [r.refresh_secs, r.selection_secs, r.compute_secs, r.upload_secs, r.wait_secs]
            {
                assert!(part >= 0.0, "{name} round {}: negative component", r.round);
            }
        }
        let path = dir.join(format!("sim_{name}.jsonl"));
        rep.write_jsonl(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() > 5 + 1, "{name}: JSONL too short");
        assert!(text.lines().next().unwrap().contains(&format!("\"scenario\":\"{name}\"")));
        entries.push(rep.bench_entry_json(0.0));
    }
    let agg = bench_json(&entries);
    assert_eq!(agg.matches("\"scenario\"").count(), 4);
    assert!(agg.contains("\"event_digest\""));
    let out = dir.join("BENCH_sim.json");
    std::fs::write(&out, agg).unwrap();
    assert!(std::fs::metadata(&out).unwrap().len() > 0);
}

#[test]
fn heavy_tail_scenario_cuts_more_stragglers_than_baseline() {
    use feddde::config::SimConfig;
    use feddde::sim::{Scenario, Simulator};

    let cfg = || SimConfig {
        n_clients: 60,
        rounds: 6,
        per_round: 12,
        refresh_every: 0,
        seed: 8,
        ..Default::default()
    };
    let base = Simulator::new(cfg(), Scenario::by_name("sync_baseline").unwrap())
        .unwrap()
        .run()
        .unwrap();
    let tail = Simulator::new(cfg(), Scenario::by_name("heavy_tail").unwrap())
        .unwrap()
        .run()
        .unwrap();
    let base_cut = base.totals().timed_out + base.totals().dropped;
    let tail_cut = tail.totals().timed_out + tail.totals().dropped;
    assert!(
        tail_cut > base_cut,
        "heavy_tail cut {tail_cut} vs baseline {base_cut} — straggler model inert"
    );
}

#[test]
fn chaos_scenarios_run_end_to_end_with_recovery_and_emit_fault_counters() {
    // The chaos trio each carries both an active fault plan AND a crash
    // point: every one must survive kill → recover → resume, converge to its
    // uninterrupted twin's digest, keep the four-way client partition, and
    // exercise its fault channel — then aggregate into BENCH_chaos.json.
    use feddde::config::SimConfig;
    use feddde::sim::{bench_json, run_with_recovery, Scenario};

    let dir = std::env::temp_dir().join("feddde_chaos_it");
    std::fs::create_dir_all(&dir).unwrap();
    let mut entries = Vec::new();
    for name in ["regional_outage", "flaky_uplink", "byzantine_summaries"] {
        let cfg = SimConfig {
            n_clients: 40,
            rounds: 6,
            per_round: 8,
            refresh_every: 2,
            seed: 23,
            ..Default::default()
        };
        let sc = Scenario::by_name(name).unwrap();
        assert!(!sc.fault.is_inert(), "{name} must carry an active fault plan");
        let r = run_with_recovery(cfg, sc).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(r.report.rounds.len(), 6, "{name}: lost rounds");
        assert!(r.recovered_rounds > 0, "{name}: crash recovered nothing");
        assert_eq!(r.report.event_digest(), r.uninterrupted_digest, "{name}: digest forked");
        let t = r.report.totals();
        assert!(t.completed > 0, "{name}: nothing ever completed");
        for rr in &r.report.rounds {
            assert_eq!(
                rr.completed + rr.dropped + rr.timed_out + rr.failed,
                rr.selected,
                "{name} round {}: partition leaked a client",
                rr.round
            );
        }
        // The deterministic fault channels must actually fire (regional
        // outage only masks availability, so it has no counter of its own).
        match name {
            "flaky_uplink" => assert!(t.retries > 0, "{name}: no retries issued"),
            "byzantine_summaries" => {
                assert!(t.summary_rejects > 0, "{name}: no summaries rejected")
            }
            _ => {}
        }
        let journal_path = dir.join(format!("{name}.journal"));
        std::fs::write(&journal_path, r.journal.to_jsonl()).unwrap();
        entries.push(r.report.chaos_entry_json(0.0, 0.0));
    }
    let agg = bench_json(&entries);
    assert_eq!(agg.matches("\"scenario\"").count(), 3);
    assert!(agg.contains("\"retries\"") && agg.contains("\"degraded_rounds\""));
    let out = dir.join("BENCH_chaos.json");
    std::fs::write(&out, &agg).unwrap();
    assert!(std::fs::metadata(&out).unwrap().len() > 0);
}
