//! Determinism oracle suite for the fleet refresh subsystem
//! (`coordinator::summaries`): the parallel path must equal the serial path
//! element-for-element, cached refreshes must equal cold refreshes, the
//! streaming fused generate→coreset→project path must equal the
//! materialize-then-summarize path, bounded-store evictions must recompute
//! to the same bits (f32 and int8-quantized arenas alike), the quantized
//! store's raw codes must be bitwise identical across thread counts and
//! reruns, and the mini-batch clustering backend must be thread-count
//! invariant and close to Lloyd's in quality.
//!
//! Everything here runs against the pure-Rust `JlSummary` engine and a
//! manifest-free `Engine`, so the oracle holds in every environment — no AOT
//! artifacts or PJRT backend required. `FEDDDE_THREADS` is exercised through
//! `RefreshOptions::threads` (the same value the env var feeds via
//! `util::parallel::default_threads`); passing it explicitly keeps the tests
//! independent of process-global env state.

use feddde::cluster::kmeans::{self, KmeansConfig};
use feddde::cluster::{minibatch, ClusterBackend, MinibatchConfig};
use feddde::config::SimConfig;
use feddde::coordinator::{FleetRefresher, RefreshOptions, RefreshResult};
use feddde::data::{DatasetSpec, DriftSchedule, Generator, Partition};
use feddde::device::{DeviceProfile, FleetModel};
use feddde::runtime::Engine;
use feddde::sim::{Scenario, SimReport, Simulator};
use feddde::summary::{JlSummary, SummaryEngine};
use feddde::util::stats;

struct Fixture {
    spec: DatasetSpec,
    partition: Partition,
    generator: Generator,
    fleet: Vec<DeviceProfile>,
    engine: Engine,
    summary: JlSummary,
}

fn fixture(n_clients: usize) -> Fixture {
    let spec = if n_clients == 0 {
        DatasetSpec::tiny()
    } else {
        DatasetSpec::tiny().with_clients(n_clients)
    };
    let partition = Partition::build(&spec);
    let generator = Generator::new(&spec);
    let fleet = FleetModel::default().sample_fleet(spec.n_clients);
    let engine = Engine::without_artifacts().unwrap();
    let summary = JlSummary::new(&spec);
    Fixture { spec, partition, generator, fleet, engine, summary }
}

fn refresh(
    fx: &Fixture,
    opts: RefreshOptions,
    drift: &DriftSchedule,
    round: usize,
    seed: u64,
) -> RefreshResult {
    FleetRefresher::new(opts)
        .refresh(
            &fx.engine,
            &fx.summary,
            &fx.partition,
            &fx.generator,
            &fx.fleet,
            drift,
            round,
            fx.spec.n_groups,
            seed,
        )
        .unwrap()
}

/// Bitwise equality of two refresh results (summaries, clusters, simulated
/// device seconds). Measured wall-clock fields are deliberately excluded.
fn assert_bitwise_equal(a: &RefreshResult, b: &RefreshResult, what: &str) {
    assert_eq!(a.summaries.rows(), b.summaries.rows(), "{what}: row count");
    assert_eq!(a.summaries.cols(), b.summaries.cols(), "{what}: col count");
    for (i, (x, y)) in a.summaries.data().iter().zip(b.summaries.data()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: summaries differ at flat index {i}: {x} vs {y}"
        );
    }
    assert_eq!(a.clusters, b.clusters, "{what}: cluster assignments differ");
    assert_eq!(a.device_secs.len(), b.device_secs.len(), "{what}: device_secs len");
    for (i, (x, y)) in a.device_secs.iter().zip(&b.device_secs).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: device_secs differ at client {i}: {x} vs {y}"
        );
    }
}

fn lloyd_opts(threads: usize) -> RefreshOptions {
    RefreshOptions {
        threads,
        backend: ClusterBackend::Lloyd,
        use_cache: false,
        ..Default::default()
    }
}

#[test]
fn parallel_refresh_equals_serial_for_all_thread_counts() {
    let fx = fixture(0);
    let drift = DriftSchedule::none();
    let serial = refresh(&fx, lloyd_opts(1), &drift, 0, 7);
    for threads in [2, 4, 8] {
        let parallel = refresh(&fx, lloyd_opts(threads), &drift, 0, 7);
        assert_bitwise_equal(&serial, &parallel, &format!("threads=1 vs {threads}"));
    }
}

#[test]
fn parallel_refresh_equals_serial_mid_drift() {
    // Thread-count invariance must also hold when clients sit in different
    // drift phases (irregular per-client work).
    let fx = fixture(48);
    let drift = DriftSchedule::at(vec![2, 5], 0.4);
    for round in [0, 3, 6] {
        let serial = refresh(&fx, lloyd_opts(1), &drift, round, 11);
        let parallel = refresh(&fx, lloyd_opts(8), &drift, round, 11);
        assert_bitwise_equal(&serial, &parallel, &format!("round {round}"));
    }
}

#[test]
fn cached_refresh_equals_cold_refresh_under_drift() {
    // The central cache oracle: at every round of a drift schedule, a
    // refresher that reuses cached rows must equal a cold refresher that
    // recomputes everything — bitwise.
    let fx = fixture(0);
    let drift = DriftSchedule::at(vec![3, 7], 0.5);
    let seed = 9;
    let mut cached = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Lloyd,
        ..Default::default()
    });
    let mut saw_partial_recompute = false;
    for round in 0..10 {
        let warm = cached
            .refresh(
                &fx.engine,
                &fx.summary,
                &fx.partition,
                &fx.generator,
                &fx.fleet,
                &drift,
                round,
                fx.spec.n_groups,
                seed,
            )
            .unwrap();
        let cold = refresh(&fx, lloyd_opts(0), &drift, round, seed);
        assert_bitwise_equal(&cold, &warm, &format!("cold vs cached at round {round}"));
        if round > 0 && !warm.recomputed.is_empty() && warm.recomputed.len() < fx.spec.n_clients
        {
            saw_partial_recompute = true;
        }
    }
    assert!(
        saw_partial_recompute,
        "drift schedule never produced a partial recompute — cache untested"
    );
    assert!(cached.store().unwrap().hits() > 0);
}

#[test]
fn fused_refresh_equals_materialized_for_all_thread_counts() {
    // The tentpole oracle: the streaming fused pipeline (labels → coreset →
    // tile-streamed projection, zero raw-data materialization) is bitwise
    // equal to materialize-then-summarize, at every thread count, with
    // clients spread across drift phases (irregular per-client work).
    let fx = fixture(48);
    let drift = DriftSchedule::at(vec![2, 5], 0.4);
    let opts = |threads, fused| RefreshOptions {
        threads,
        backend: ClusterBackend::Lloyd,
        use_cache: false,
        fused,
        ..Default::default()
    };
    for round in [0usize, 6] {
        let materialized = refresh(&fx, opts(1, false), &drift, round, 31);
        for threads in [1, 4, 8] {
            let fused = refresh(&fx, opts(threads, true), &drift, round, 31);
            assert_bitwise_equal(
                &materialized,
                &fused,
                &format!("fused(threads={threads}) vs materialized at round {round}"),
            );
        }
    }
}

#[test]
fn fused_equals_materialized_across_cache_hits_and_misses() {
    // Two cached refreshers — one fused, one materialized — walked through a
    // drift schedule must agree bitwise at every round, with identical
    // recompute sets (hits and misses land on the same clients).
    let fx = fixture(0);
    let drift = DriftSchedule::at(vec![2, 6], 0.5);
    let seed = 33;
    let mk = |fused| {
        FleetRefresher::new(RefreshOptions {
            backend: ClusterBackend::Lloyd,
            fused,
            ..Default::default()
        })
    };
    let mut fused = mk(true);
    let mut materialized = mk(false);
    let mut saw_hit_round = false;
    for round in 0..9 {
        let run = |r: &mut FleetRefresher| {
            r.refresh(
                &fx.engine,
                &fx.summary,
                &fx.partition,
                &fx.generator,
                &fx.fleet,
                &drift,
                round,
                fx.spec.n_groups,
                seed,
            )
            .unwrap()
        };
        let a = run(&mut fused);
        let b = run(&mut materialized);
        assert_bitwise_equal(&a, &b, &format!("fused vs materialized, cached, round {round}"));
        assert_eq!(a.recomputed, b.recomputed, "recompute sets diverged at round {round}");
        if a.recomputed.len() < fx.spec.n_clients {
            saw_hit_round = true;
        }
    }
    assert!(saw_hit_round, "schedule never exercised cache hits");
    assert!(fused.store().unwrap().hits() > 0);
}

#[test]
fn bounded_store_evictions_recompute_bitwise() {
    // Memory-bounded store: with capacity for only a third of the fleet the
    // refresher thrashes through LRU evictions, yet every refresh result is
    // bitwise identical to the unbounded refresher's — evicted rows lose
    // nothing but time.
    let fx = fixture(48);
    let drift = DriftSchedule::at(vec![3], 0.5);
    let seed = 37;
    let mut bounded = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Lloyd,
        store_capacity: fx.spec.n_clients / 3,
        ..Default::default()
    });
    let mut unbounded = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Lloyd,
        ..Default::default()
    });
    let mut total_evicted = 0;
    for round in 0..6 {
        let run = |r: &mut FleetRefresher| {
            r.refresh(
                &fx.engine,
                &fx.summary,
                &fx.partition,
                &fx.generator,
                &fx.fleet,
                &drift,
                round,
                fx.spec.n_groups,
                seed,
            )
            .unwrap()
        };
        let b = run(&mut bounded);
        let u = run(&mut unbounded);
        assert_bitwise_equal(&u, &b, &format!("bounded vs unbounded at round {round}"));
        total_evicted += b.evicted;
        assert!(
            b.store.rows <= fx.spec.n_clients / 3,
            "store exceeded its capacity: {} rows",
            b.store.rows
        );
    }
    assert!(total_evicted > 0, "capacity bound never forced an eviction — test inert");
    assert_eq!(unbounded.store().unwrap().evictions(), 0);
}

#[test]
fn quantized_store_is_bitwise_identical_across_threads_and_reruns() {
    // Quantization oracle: with `store_quantized` on, the dequantized
    // summaries, cluster assignments, device seconds, AND the raw store
    // contents — every i8 code plus each row's scale/zero-point — must be
    // bitwise identical across refresh thread counts and across reruns from
    // the same seed. threads=1 appears twice: its second run is the rerun
    // check.
    let fx = fixture(48);
    let drift = DriftSchedule::at(vec![2, 5], 0.4);
    let seed = 41;
    let run = |threads: usize| {
        let mut r = FleetRefresher::new(RefreshOptions {
            threads,
            backend: ClusterBackend::Lloyd,
            store_quantized: true,
            ..Default::default()
        });
        let mut last = None;
        for round in 0..5 {
            last = Some(
                r.refresh(
                    &fx.engine,
                    &fx.summary,
                    &fx.partition,
                    &fx.generator,
                    &fx.fleet,
                    &drift,
                    round,
                    fx.spec.n_groups,
                    seed,
                )
                .unwrap(),
            );
        }
        (r, last.unwrap())
    };
    let (base_r, base) = run(1);
    let base_store = base_r.store().unwrap();
    assert!(base_store.is_quantized(), "store_quantized did not produce a quantized store");
    assert!(!base_store.is_empty());
    for threads in [1usize, 4, 8] {
        let (r, res) = run(threads);
        assert_bitwise_equal(&base, &res, &format!("quant threads=1 vs {threads}"));
        let s = r.store().unwrap();
        assert_eq!(s.len(), base_store.len(), "quant store rows at threads={threads}");
        assert_eq!(s.stats().allocated, base_store.stats().allocated);
        for slot in 0..s.stats().allocated {
            assert_eq!(
                s.qrow(slot),
                base_store.qrow(slot),
                "quant codes differ at slot {slot}, threads={threads}"
            );
            let (a, b) = (s.qparams_of(slot), base_store.qparams_of(slot));
            assert_eq!(a.scale.to_bits(), b.scale.to_bits(), "scale at slot {slot}");
            assert_eq!(a.zero.to_bits(), b.zero.to_bits(), "zero at slot {slot}");
        }
    }
}

#[test]
fn bounded_quantized_store_evictions_recompute_bitwise() {
    // Quantized twin of the eviction oracle above: a capacity-bound int8
    // store thrashes through LRU evictions, and every re-inserted row must
    // re-quantize to the same codes — the bounded refresher stays bitwise
    // equal to the unbounded quantized one at every round.
    let fx = fixture(48);
    let drift = DriftSchedule::at(vec![3], 0.5);
    let seed = 43;
    let mk = |capacity| {
        FleetRefresher::new(RefreshOptions {
            backend: ClusterBackend::Lloyd,
            store_quantized: true,
            store_capacity: capacity,
            ..Default::default()
        })
    };
    let mut bounded = mk(fx.spec.n_clients / 3);
    let mut unbounded = mk(0);
    let mut total_evicted = 0;
    for round in 0..6 {
        let run = |r: &mut FleetRefresher| {
            r.refresh(
                &fx.engine,
                &fx.summary,
                &fx.partition,
                &fx.generator,
                &fx.fleet,
                &drift,
                round,
                fx.spec.n_groups,
                seed,
            )
            .unwrap()
        };
        let b = run(&mut bounded);
        let u = run(&mut unbounded);
        assert_bitwise_equal(&u, &b, &format!("quant bounded vs unbounded at round {round}"));
        assert!(b.store.quantized && u.store.quantized, "round {round}: store not quantized");
        total_evicted += b.evicted;
        assert!(
            b.store.rows <= fx.spec.n_clients / 3,
            "store exceeded its capacity: {} rows",
            b.store.rows
        );
    }
    assert!(total_evicted > 0, "capacity bound never forced an eviction — test inert");
    assert_eq!(unbounded.store().unwrap().evictions(), 0);
}

#[test]
fn cache_recomputes_nothing_without_drift() {
    let fx = fixture(0);
    let drift = DriftSchedule::none();
    let mut refresher = FleetRefresher::new(RefreshOptions {
        backend: ClusterBackend::Lloyd,
        ..Default::default()
    });
    let first = refresher
        .refresh(
            &fx.engine,
            &fx.summary,
            &fx.partition,
            &fx.generator,
            &fx.fleet,
            &drift,
            0,
            fx.spec.n_groups,
            5,
        )
        .unwrap();
    assert_eq!(first.recomputed.len(), fx.spec.n_clients);
    for round in 1..5 {
        let next = refresher
            .refresh(
                &fx.engine,
                &fx.summary,
                &fx.partition,
                &fx.generator,
                &fx.fleet,
                &drift,
                round,
                fx.spec.n_groups,
                5,
            )
            .unwrap();
        assert!(next.recomputed.is_empty(), "round {round} recomputed {:?}", next.recomputed);
        assert_bitwise_equal(&first, &next, &format!("cached round {round}"));
    }
}

#[test]
fn minibatch_backend_is_thread_count_invariant() {
    let fx = fixture(64);
    let drift = DriftSchedule::none();
    let opts = |threads| RefreshOptions {
        threads,
        backend: ClusterBackend::Minibatch,
        use_cache: false,
        ..Default::default()
    };
    let serial = refresh(&fx, opts(1), &drift, 0, 13);
    let parallel = refresh(&fx, opts(8), &drift, 0, 13);
    assert_bitwise_equal(&serial, &parallel, "minibatch threads=1 vs 8");
}

#[test]
fn auto_backend_switches_to_minibatch_at_scale() {
    // Above the threshold the auto backend must still produce a valid,
    // thread-count-invariant clustering.
    let fx = fixture(600); // >= MINIBATCH_AUTO_THRESHOLD
    let drift = DriftSchedule::none();
    let opts = |threads| RefreshOptions {
        threads,
        backend: ClusterBackend::Auto,
        use_cache: false,
        ..Default::default()
    };
    let a = refresh(&fx, opts(1), &drift, 0, 17);
    let b = refresh(&fx, opts(4), &drift, 0, 17);
    assert_bitwise_equal(&a, &b, "auto backend at 600 clients");
    let ari = stats::adjusted_rand_index(&a.clusters, &fx.partition.group_truth());
    assert!(ari > 0.2, "auto/minibatch clustering lost group structure: ari={ari}");
}

#[test]
fn minibatch_ari_within_tolerance_of_lloyds_on_tiny() {
    // The satellite oracle: mini-batch assignments recover the planted
    // groups (ARI vs partition.group_truth()) within 0.1 of Lloyd's.
    let fx = fixture(0);
    let drift = DriftSchedule::none();
    let truth = fx.partition.group_truth();
    let lloyd = refresh(&fx, lloyd_opts(0), &drift, 0, 7);
    let mb = refresh(
        &fx,
        RefreshOptions {
            backend: ClusterBackend::Minibatch,
            use_cache: false,
            ..Default::default()
        },
        &drift,
        0,
        7,
    );
    let ari_lloyd = stats::adjusted_rand_index(&lloyd.clusters, &truth);
    let ari_mb = stats::adjusted_rand_index(&mb.clusters, &truth);
    assert!(
        ari_mb >= ari_lloyd - 0.1,
        "minibatch ARI {ari_mb:.3} more than 0.1 below Lloyd's {ari_lloyd:.3}"
    );
}

// ---------------------------------------------------------------------------
// Fleet-simulator oracle: the simulated event stream — every popped event's
// (time, id, round, kind, client) — and the per-round reports must be
// bitwise identical across refresh thread counts and across replays from
// the same seed. Serialized JSONL is compared (f64s print shortest-round-
// trip, so string equality == bitwise equality), plus the digest quoted in
// BENCH_sim.json.

fn run_sim(scenario: &str, threads: usize, seed: u64) -> SimReport {
    let cfg = SimConfig {
        n_clients: 40,
        rounds: 6,
        per_round: 8,
        refresh_every: 2,
        threads,
        seed,
        ..Default::default()
    };
    Simulator::new(cfg, Scenario::by_name(scenario).unwrap())
        .unwrap()
        .run()
        .unwrap()
}

fn assert_sim_bitwise_equal(a: &SimReport, b: &SimReport, what: &str) {
    assert_eq!(a.events.len(), b.events.len(), "{what}: event count");
    for (i, (x, y)) in a.events.iter().zip(&b.events).enumerate() {
        assert_eq!(x.time.to_bits(), y.time.to_bits(), "{what}: event {i} time");
        assert_eq!((x.id, x.round, x.kind, x.client), (y.id, y.round, y.kind, y.client),
            "{what}: event {i} identity");
    }
    assert_eq!(a.events_jsonl(), b.events_jsonl(), "{what}: serialized stream");
    assert_eq!(a.event_digest(), b.event_digest(), "{what}: digest");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.to_json(), y.to_json(), "{what}: round {} report", x.round);
    }
}

#[test]
fn sim_event_stream_is_thread_count_invariant() {
    // The refresher is the only parallel component in the simulator; its
    // bitwise thread invariance must carry through to the event stream.
    for scenario in ["sync_baseline", "heavy_tail", "drift_burst"] {
        let t1 = run_sim(scenario, 1, 11);
        for threads in [4, 8] {
            let tn = run_sim(scenario, threads, 11);
            assert_sim_bitwise_equal(&t1, &tn, &format!("{scenario} threads 1 vs {threads}"));
        }
    }
}

#[test]
fn sim_replay_from_seed_is_bitwise_identical() {
    for scenario in ["straggler_cut", "partial_async", "flash_crowd"] {
        let a = run_sim(scenario, 0, 23);
        let b = run_sim(scenario, 0, 23);
        assert_sim_bitwise_equal(&a, &b, &format!("{scenario} replay"));
        assert!(!a.events.is_empty(), "{scenario} produced no events");
    }
    // A different seed must actually change the stream (the oracle is not
    // vacuously comparing constants).
    let a = run_sim("straggler_cut", 0, 23);
    let c = run_sim("straggler_cut", 0, 24);
    assert_ne!(a.event_digest(), c.event_digest(), "seed had no effect");
}

// ---------------------------------------------------------------------------
// Event-journal replay oracle: a simulator recovered from a run's journal
// re-executes every journaled round under the machine's replay cursor (each
// re-derived transition asserted equal to the journaled one bitwise), and the
// finished replay must reproduce the live run — event stream, round reports,
// and journal digest — at every refresh thread count. The crash scenarios
// exercise the same machinery through a kill mid-journal.

fn sim_cfg(threads: usize, seed: u64) -> SimConfig {
    SimConfig {
        n_clients: 40,
        rounds: 6,
        per_round: 8,
        refresh_every: 2,
        threads,
        seed,
        ..Default::default()
    }
}

#[test]
fn journal_replay_reproduces_the_live_run_at_every_thread_count() {
    for threads in [1usize, 4, 8] {
        let cfg = sim_cfg(threads, 11);
        let sc = Scenario::by_name("sync_baseline").unwrap();
        let (live_rep, live_journal) =
            Simulator::new(cfg.clone(), sc.clone()).unwrap().run_journaled().unwrap();
        // Round-trip the journal through its serialized form, then replay.
        let parsed = feddde::coordinator::EventJournal::parse(&live_journal.to_jsonl()).unwrap();
        let replayed = Simulator::recover(cfg, sc, &parsed).unwrap();
        let (rep, journal) = replayed.run_journaled().unwrap();
        assert_sim_bitwise_equal(&live_rep, &rep, &format!("replay threads={threads}"));
        assert_eq!(
            journal.digest(),
            live_journal.digest(),
            "replay journal digest diverged at threads={threads}"
        );
    }
}

#[test]
fn crash_scenarios_recover_to_the_uninterrupted_digest() {
    for name in ["coordinator_failure", "mid_round_restart"] {
        for threads in [1usize, 4, 8] {
            let sc = Scenario::by_name(name).unwrap();
            // run_with_recovery bails internally unless the recovered run's
            // journal AND event digests equal the uninterrupted twin's; the
            // asserts below keep the oracle visible here too.
            let r = feddde::sim::run_with_recovery(sim_cfg(threads, 17), sc).unwrap();
            assert!(r.recovered_rounds > 0, "{name}: recovery replayed nothing");
            assert_eq!(
                r.report.event_digest(),
                r.uninterrupted_digest,
                "{name} threads={threads}: digests diverged"
            );
            assert_eq!(r.report.rounds.len(), 6, "{name}: resumed run incomplete");
        }
    }
}

// ---------------------------------------------------------------------------
// Fault-fabric oracle: every fault draw (outage membership, upload failures,
// retry backoffs, heartbeat loss, corruption, quarantine decisions) is a
// seeded substream, so the chaos scenarios must be exactly as deterministic
// as the clean ones — bitwise identical event streams across thread counts
// and reruns, and kill → recover → resume runs matching their uninterrupted
// twins digest-for-digest.

#[test]
fn chaos_event_streams_are_thread_count_invariant() {
    for scenario in ["regional_outage", "flaky_uplink", "byzantine_summaries"] {
        let t1 = run_sim(scenario, 1, 29);
        for threads in [4, 8] {
            let tn = run_sim(scenario, threads, 29);
            assert_sim_bitwise_equal(&t1, &tn, &format!("{scenario} threads 1 vs {threads}"));
        }
        assert!(!t1.events.is_empty(), "{scenario} produced no events");
    }
}

#[test]
fn chaos_replay_from_seed_is_bitwise_identical() {
    for scenario in ["regional_outage", "flaky_uplink", "byzantine_summaries"] {
        let a = run_sim(scenario, 0, 31);
        let b = run_sim(scenario, 0, 31);
        assert_sim_bitwise_equal(&a, &b, &format!("{scenario} replay"));
        let c = run_sim(scenario, 0, 32);
        assert_ne!(
            a.event_digest(),
            c.event_digest(),
            "{scenario}: seed had no effect on the fault stream"
        );
    }
}

#[test]
fn chaos_scenarios_recover_to_the_uninterrupted_digest() {
    // Acceptance: with faults enabled, every chaos scenario's kill → recover
    // → resume run matches its uninterrupted twin's digests — retry events,
    // quarantine decisions and degraded closes replay bitwise.
    for name in ["regional_outage", "flaky_uplink", "byzantine_summaries"] {
        for threads in [1usize, 4, 8] {
            let sc = Scenario::by_name(name).unwrap();
            let r = feddde::sim::run_with_recovery(sim_cfg(threads, 37), sc).unwrap();
            assert!(r.recovered_rounds > 0, "{name}: recovery replayed nothing");
            assert_eq!(
                r.report.event_digest(),
                r.uninterrupted_digest,
                "{name} threads={threads}: digests diverged"
            );
            assert_eq!(r.report.rounds.len(), 6, "{name}: resumed run incomplete");
        }
    }
}

// ---------------------------------------------------------------------------
// Sharded-fleet oracle: the coordinator-shard count and lazy arrival
// sampling are pure execution strategies — N coordinator shards merge to
// the flat coordinator's bits, and a lazily-materialized cohort reproduces
// the eagerly-built fleet's event stream byte for byte (for the
// cohort-invariant policies; `cluster` reclusters over the cohort and
// `round_robin` cursors over the full fleet, so they are exercised through
// the engine's own unit tests instead).

fn run_sim_sharded(scenario: &str, policy: &str, shards: usize, lazy: bool) -> SimReport {
    let cfg = SimConfig {
        n_clients: 40,
        rounds: 6,
        per_round: 8,
        refresh_every: 2,
        policy: policy.into(),
        shards,
        lazy_arrivals: lazy,
        seed: 47,
        ..Default::default()
    };
    Simulator::new(cfg, Scenario::by_name(scenario).unwrap())
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn sharded_simulator_is_shard_count_invariant() {
    // Acceptance oracle: shards in {1, 4, 16} produce bit-identical merged
    // results — event stream, round reports, digests. The hier diagnostics
    // block differs (it only exists for S > 1), so rounds are compared
    // through the shared fields rather than raw JSON.
    for scenario in ["sync_baseline", "straggler_cut", "drift_burst"] {
        let flat = run_sim_sharded(scenario, "cluster", 1, false);
        for shards in [4usize, 16] {
            let sharded = run_sim_sharded(scenario, "cluster", shards, false);
            assert_eq!(flat.events_jsonl(), sharded.events_jsonl(),
                "{scenario}: shards={shards} changed the event stream");
            assert_eq!(flat.event_digest(), sharded.event_digest(),
                "{scenario}: shards={shards} changed the digest");
            for (a, b) in flat.rounds.iter().zip(&sharded.rounds) {
                assert_eq!(a.t_end.to_bits(), b.t_end.to_bits(),
                    "{scenario} round {}: clock diverged at shards={shards}", a.round);
                assert_eq!(a.completed, b.completed,
                    "{scenario} round {}: completions diverged", a.round);
                assert_eq!(a.refresh_secs.to_bits(), b.refresh_secs.to_bits(),
                    "{scenario} round {}: refresh time diverged", a.round);
            }
        }
    }
}

#[test]
fn explicit_flat_eager_config_matches_the_default_bitwise() {
    // shards=1 + lazy_arrivals=false spelled out must reproduce the
    // implicit default byte for byte — the new knobs at their inert
    // settings cannot perturb the pre-existing stream.
    let default_run = run_sim("straggler_cut", 0, 47);
    let cfg = SimConfig {
        n_clients: 40,
        rounds: 6,
        per_round: 8,
        refresh_every: 2,
        shards: 1,
        lazy_arrivals: false,
        seed: 47,
        ..Default::default()
    };
    let explicit = Simulator::new(cfg, Scenario::by_name("straggler_cut").unwrap())
        .unwrap()
        .run()
        .unwrap();
    assert_sim_bitwise_equal(&default_run, &explicit, "explicit flat/eager vs default");
    for (a, b) in default_run.rounds.iter().zip(&explicit.rounds) {
        assert!(b.hier.is_none(), "flat run emitted a hier block at round {}", a.round);
        assert_eq!(a.to_json(), b.to_json(), "round {} JSON diverged", a.round);
    }
}

#[test]
fn lazy_arrival_sampling_is_bitwise_inert() {
    // Only clients drawn active are materialized under lazy arrivals, yet
    // the event stream, reports and digests must match the eager run for
    // every cohort-invariant policy on both calm and churning scenarios.
    for policy in ["random", "oort", "powd"] {
        for scenario in ["sync_baseline", "diurnal", "flash_crowd"] {
            let eager = run_sim_sharded(scenario, policy, 1, false);
            let lazy = run_sim_sharded(scenario, policy, 1, true);
            assert_sim_bitwise_equal(&eager, &lazy, &format!("{policy}/{scenario} lazy vs eager"));
        }
    }
}

#[test]
fn lazy_sharded_chaos_run_is_reproducible_and_invariant() {
    // The full stack at once: lazy arrivals + 4 coordinator shards under
    // the fault fabric must self-reproduce and match the lazy flat run.
    let a = run_sim_sharded("regional_outage", "random", 4, true);
    let b = run_sim_sharded("regional_outage", "random", 4, true);
    assert_sim_bitwise_equal(&a, &b, "lazy sharded chaos replay");
    let flat = run_sim_sharded("regional_outage", "random", 1, true);
    assert_eq!(a.events_jsonl(), flat.events_jsonl(), "shards=4 changed the chaos stream");
}

// ---------------------------------------------------------------------------
// Telemetry oracle: span tracing is observation, not participation. With
// tracing off the tracer emits nothing and the run is bitwise identical to a
// traced run's streams and journals; with tracing on the trace bytes and
// digest are invariant across refresh thread counts and reruns; and the
// `profile` inspector's per-round totals reproduce the reported round times
// bit for bit (each root `round` span is closed with the report row's own
// f64 bits).

fn run_traced_sim(scenario: &str, threads: usize, seed: u64, trace: bool) -> feddde::sim::SimRun {
    let cfg = SimConfig {
        n_clients: 40,
        rounds: 6,
        per_round: 8,
        refresh_every: 2,
        threads,
        seed,
        trace: if trace { "trace.jsonl".into() } else { String::new() },
        ..Default::default()
    };
    Simulator::new(cfg, Scenario::by_name(scenario).unwrap())
        .unwrap()
        .run_traced()
        .unwrap()
}

#[test]
fn tracing_is_a_bitwise_noop_on_streams_and_journals() {
    for scenario in ["sync_baseline", "flaky_uplink"] {
        let off = run_traced_sim(scenario, 0, 53, false);
        let on = run_traced_sim(scenario, 0, 53, true);
        assert_sim_bitwise_equal(&off.report, &on.report, &format!("{scenario} trace off vs on"));
        assert_eq!(
            off.journal.to_jsonl(),
            on.journal.to_jsonl(),
            "{scenario}: tracing changed the journal bytes"
        );
        assert_eq!(
            off.journal.digest(),
            on.journal.digest(),
            "{scenario}: tracing changed the journal digest"
        );
        assert_eq!(off.tracer.to_jsonl(), "", "{scenario}: disabled tracer emitted spans");
        assert!(!on.tracer.to_jsonl().is_empty(), "{scenario}: enabled tracer emitted nothing");
    }
}

#[test]
fn trace_bytes_and_digest_are_invariant_across_threads_and_reruns() {
    // threads=1 appears twice: its second run is the rerun check.
    for scenario in ["diurnal", "regional_outage"] {
        let base = run_traced_sim(scenario, 1, 59, true);
        for threads in [1usize, 4, 8] {
            let other = run_traced_sim(scenario, threads, 59, true);
            assert_eq!(
                base.tracer.to_jsonl(),
                other.tracer.to_jsonl(),
                "{scenario}: trace bytes diverged at threads={threads}"
            );
            assert_eq!(
                base.tracer.digest(),
                other.tracer.digest(),
                "{scenario}: trace digest diverged at threads={threads}"
            );
        }
    }
}

#[test]
fn profile_reproduces_round_times_from_span_totals_bitwise() {
    use feddde::obs::profile::{check_well_nested, parse_trace, round_totals};
    for scenario in ["straggler_cut", "byzantine_summaries"] {
        let run = run_traced_sim(scenario, 0, 61, true);
        let spans = parse_trace(&run.tracer.to_jsonl()).unwrap();
        check_well_nested(&spans, 1e-9).unwrap_or_else(|e| panic!("{scenario}: {e}"));
        let totals = round_totals(&spans);
        assert_eq!(totals.len(), run.report.rounds.len(), "{scenario}: root span count");
        for ((round, total), row) in totals.iter().zip(&run.report.rounds) {
            assert_eq!(*round, row.round as u64, "{scenario}: root span round order");
            assert_eq!(
                total.to_bits(),
                row.round_secs.to_bits(),
                "{scenario} round {round}: profile total != reported round_secs"
            );
        }
    }
}

#[test]
fn direct_minibatch_and_lloyd_agree_on_separated_summaries() {
    // Belt-and-braces on the raw engines (no refresher): same summary
    // matrix, both backends, ARI within 0.1.
    let fx = fixture(96);
    let drift = DriftSchedule::none();
    let r = refresh(&fx, lloyd_opts(0), &drift, 0, 23);
    let balanced = feddde::cluster::balance_blocks(&r.summaries, &fx.summary.blocks());
    let mut kcfg = KmeansConfig::new(fx.spec.n_groups);
    kcfg.seed = 23;
    let lloyd = kmeans::fit(&balanced, &kcfg);
    let mut mcfg = MinibatchConfig::new(fx.spec.n_groups);
    mcfg.seed = 23;
    let mb = minibatch::fit(&balanced, &mcfg);
    let truth = fx.partition.group_truth();
    let ari_lloyd = stats::adjusted_rand_index(&lloyd.assignments, &truth);
    let ari_mb = stats::adjusted_rand_index(&mb.assignments, &truth);
    assert!(
        ari_mb >= ari_lloyd - 0.1,
        "minibatch {ari_mb:.3} vs lloyd {ari_lloyd:.3}"
    );
}
