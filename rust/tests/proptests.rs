//! Cross-module property tests (the offline stand-in for proptest): fuzz
//! coordinator-level invariants over generated fleets, datasets, and
//! clusterings.

use feddde::cluster::{dbscan, kmeans, ClusterBackend, Pruning};
use feddde::config::SimConfig;
use feddde::coordinator::fedavg::fedavg;
use feddde::coordinator::{
    CoordinatorMachine, EventJournal, FleetRefresher, JournalHeader, RefreshOptions,
    Transition,
};
use feddde::data::{coreset, DatasetSpec, DriftSchedule, Generator, Partition};
use feddde::device::FleetModel;
use feddde::runtime::Engine;
use feddde::selection::{
    self, validate_selection, ClientView, ClusterSelection, SelectionPolicy, STRATEGY_NAMES,
};
use feddde::sim::{
    Aggregation, AvailabilityModel, FaultPlan, Scenario, Simulator, StragglerModel,
};
use feddde::summary::JlSummary;
use feddde::util::mat::Mat;
use feddde::util::proptest::check;
use feddde::util::rng::Rng;
use feddde::util::stats;

#[test]
fn coreset_label_counts_never_exceed_client_counts() {
    check(20, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let part = &partition.clients[g.usize_in(0, partition.clients.len() - 1)];
        let ds = generator.client_dataset(part, 0);
        let k = g.usize_in(1, 48);
        let mut rng = Rng::new(g.case as u64);
        let idxs = coreset::coreset_indices(&ds, spec.classes, k, &mut rng);
        assert_eq!(idxs.len(), k.min(ds.n));
        let full = ds.label_counts(spec.classes);
        let mut sel = vec![0usize; spec.classes];
        for &i in &idxs {
            sel[ds.labels[i] as usize] += 1;
        }
        for c in 0..spec.classes {
            assert!(sel[c] <= full[c], "class {c}: coreset {} > client {}", sel[c], full[c]);
        }
    });
}

#[test]
fn coreset_proportions_approximate_client_distribution() {
    check(10, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let part = &partition.clients[g.usize_in(0, partition.clients.len() - 1)];
        let ds = generator.client_dataset(part, 0);
        if ds.n < 16 {
            return;
        }
        let k = 16usize;
        let mut rng = Rng::new(g.case as u64 + 100);
        let idxs = coreset::coreset_indices(&ds, spec.classes, k, &mut rng);
        let full = ds.label_counts(spec.classes);
        let mut sel = vec![0usize; spec.classes];
        for &i in &idxs {
            sel[ds.labels[i] as usize] += 1;
        }
        for c in 0..spec.classes {
            let want = k as f64 * full[c] as f64 / ds.n as f64;
            assert!(
                (sel[c] as f64 - want).abs() <= 1.0 + 1e-9,
                "class {c}: coreset {} vs quota {want:.2}",
                sel[c]
            );
        }
    });
}

#[test]
fn one_hot_rows_sum_to_mask() {
    check(20, |g| {
        let classes = g.usize_in(2, 10);
        let n = g.usize_in(1, 64);
        let labels: Vec<u32> = (0..n)
            .map(|_| {
                if g.bool() {
                    g.usize_in(0, classes - 1) as u32
                } else {
                    u32::MAX // padding
                }
            })
            .collect();
        let oh = coreset::one_hot(&labels, classes);
        for (i, &l) in labels.iter().enumerate() {
            let row_sum: f32 = oh[i * classes..(i + 1) * classes].iter().sum();
            let want = if l == u32::MAX { 0.0 } else { 1.0 };
            assert_eq!(row_sum, want);
        }
    });
}

#[test]
fn fedavg_of_identical_updates_is_identity() {
    check(15, |g| {
        let d = g.usize_in(1, 64);
        let p = g.vec_f32(d, -3.0, 3.0);
        let n = g.usize_in(1, 6);
        let updates: Vec<(Vec<f32>, f64)> =
            (0..n).map(|i| (p.clone(), (i + 1) as f64)).collect();
        let avg = fedavg(&updates).unwrap();
        for j in 0..d {
            assert!((avg[j] - p[j]).abs() < 1e-5);
        }
    });
}

#[test]
fn kmeans_inertia_no_worse_than_random_assignment() {
    check(10, |g| {
        let n = g.usize_in(12, 60);
        let d = g.usize_in(1, 6);
        let k = g.usize_in(2, 4);
        let mut m = Mat::zeros(0, d);
        for _ in 0..n {
            m.push_row(&g.vec_f32(d, -4.0, 4.0));
        }
        let mut cfg = kmeans::KmeansConfig::new(k);
        cfg.seed = g.case as u64;
        let res = kmeans::fit(&m, &cfg);
        // Random-centroid inertia (first k points, no iterations):
        let cents = Mat::from_vec(
            (0..k).flat_map(|i| m.row(i).to_vec()).collect(),
            k,
            d,
        );
        let (_, random_inertia) = kmeans::assign(&m, &cents, 1);
        assert!(
            res.inertia <= random_inertia + 1e-6,
            "fit ({}) worse than trivial init ({})",
            res.inertia,
            random_inertia
        );
    });
}

#[test]
fn pruned_assign_matches_naive_bitwise_across_workloads() {
    // Crate-boundary version of the kernel oracle: the bound-pruned
    // assignment must equal the naive scan bitwise for random point sets,
    // dims, centroid counts, thread counts, and hint regimes.
    check(20, |g| {
        let n = g.usize_in(4, 80);
        let d = g.usize_in(1, 40);
        let k = g.usize_in(1, 8.min(n));
        let scale = [0.01f32, 1.0, 100.0][g.usize_in(0, 2)];
        let mut pts = Mat::zeros(0, d);
        for _ in 0..n {
            pts.push_row(&g.vec_f32(d, -4.0 * scale, 4.0 * scale));
        }
        let mut cents = Mat::zeros(0, d);
        for _ in 0..k {
            // centroids drawn from the points half the time (exact ties)
            if g.bool() {
                let row = pts.row(g.usize_in(0, n - 1)).to_vec();
                cents.push_row(&row);
            } else {
                cents.push_row(&g.vec_f32(d, -4.0 * scale, 4.0 * scale));
            }
        }
        let (want_a, want_i) = kmeans::assign(&pts, &cents, 1);
        let hints: Option<Vec<usize>> =
            if g.bool() { Some(want_a.clone()) } else { None };
        for threads in [1usize, 4, 8] {
            let (got_a, got_i, _) =
                kmeans::assign_pruned(&pts, &cents, threads, hints.as_deref());
            assert_eq!(got_a, want_a, "threads={threads}");
            assert_eq!(got_i.to_bits(), want_i.to_bits(), "threads={threads}");
        }
    });
}

#[test]
fn refresher_clusters_identical_with_and_without_pruning() {
    // End-to-end: a fleet refresh with bound-pruned clustering must produce
    // the same clusters as one with pruning off, for both backends.
    check(4, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let fleet = FleetModel::default().sample_fleet(spec.n_clients);
        let engine = Engine::without_artifacts().unwrap();
        let jl = JlSummary::new(&spec);
        let drift = DriftSchedule::none();
        let seed = 3000 + g.case as u64;
        let backend =
            if g.bool() { ClusterBackend::Lloyd } else { ClusterBackend::Minibatch };
        let run = |pruning: Pruning| {
            FleetRefresher::new(RefreshOptions {
                backend,
                use_cache: false,
                pruning,
                ..Default::default()
            })
            .refresh(
                &engine, &jl, &partition, &generator, &fleet, &drift, 0,
                spec.n_groups, seed,
            )
            .unwrap()
        };
        let off = run(Pruning::Off);
        let on = run(Pruning::Bounds);
        assert_eq!(off.clusters, on.clusters, "backend {backend:?}");
        for (a, b) in off.summaries.data().iter().zip(on.summaries.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    });
}

#[test]
fn dbscan_clusters_are_eps_connected() {
    // Every point in a cluster must be within eps of SOME other point of the
    // same cluster (for clusters of size >= 2) — the density-connectivity
    // invariant.
    check(8, |g| {
        let n = g.usize_in(10, 50);
        let d = g.usize_in(1, 4);
        let eps = g.f64_in(0.3, 2.0);
        let mut m = Mat::zeros(0, d);
        for _ in 0..n {
            m.push_row(&g.vec_f32(d, 0.0, 5.0));
        }
        let res = dbscan::fit(&m, &dbscan::DbscanConfig::new(eps, 3));
        for i in 0..n {
            if res.labels[i] == dbscan::NOISE {
                continue;
            }
            let mut size = 0;
            let mut connected = false;
            for j in 0..n {
                if j != i && res.labels[j] == res.labels[i] {
                    size += 1;
                    if feddde::util::mat::sqdist(m.row(i), m.row(j)).sqrt() <= eps + 1e-9 {
                        connected = true;
                    }
                }
            }
            if size >= 1 {
                assert!(connected, "point {i} isolated within its cluster");
            }
        }
    });
}

#[test]
fn ari_is_symmetric_and_bounded() {
    check(15, |g| {
        let n = g.usize_in(4, 80);
        let k = g.usize_in(1, 5.min(n));
        let a = g.labels(n, k);
        let b = g.labels(n, k);
        let ab = stats::adjusted_rand_index(&a, &b);
        let ba = stats::adjusted_rand_index(&b, &a);
        assert!((ab - ba).abs() < 1e-9, "ARI not symmetric");
        assert!(ab <= 1.0 + 1e-9, "ARI > 1");
        assert!((stats::adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-9);
    });
}

#[test]
fn partition_statistics_track_spec_across_seeds() {
    check(5, |g| {
        let mut spec = DatasetSpec::femnist().with_clients(600);
        spec.seed = g.case as u64 * 7919 + 13;
        let p = Partition::build(&spec);
        let (avg, _std, max) = p.sample_stats();
        assert!(max <= spec.samples_max);
        assert!(avg > spec.samples_avg * 0.5 && avg < spec.samples_avg * 2.0);
        // group ids are always < n_groups
        assert!(p.clients.iter().all(|c| c.group < spec.n_groups));
    });
}

#[test]
fn summary_cache_recomputes_exactly_the_drifted_clients() {
    // For random drift schedules: between two refreshes, the cached
    // refresher recomputes exactly the clients whose drift phase changed,
    // and every non-drifted row is byte-identical to the previous refresh.
    check(6, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let fleet = FleetModel::default().sample_fleet(spec.n_clients);
        let engine = Engine::without_artifacts().unwrap();
        let jl = JlSummary::new(&spec);

        let n_changes = g.usize_in(1, 3);
        let change_rounds: Vec<usize> = (0..n_changes).map(|_| g.usize_in(1, 15)).collect();
        let frac = g.f64_in(0.1, 1.0);
        let drift = DriftSchedule::at(change_rounds, frac);
        let seed = 1000 + g.case as u64;
        let r1_round = g.usize_in(0, 8);
        let r2_round = r1_round + g.usize_in(0, 8);

        let mut refresher = FleetRefresher::new(RefreshOptions {
            backend: ClusterBackend::Lloyd,
            ..Default::default()
        });
        let r1 = refresher
            .refresh(
                &engine, &jl, &partition, &generator, &fleet, &drift, r1_round,
                spec.n_groups, seed,
            )
            .unwrap();
        assert_eq!(r1.recomputed.len(), spec.n_clients, "cold refresh must compute all");

        let r2 = refresher
            .refresh(
                &engine, &jl, &partition, &generator, &fleet, &drift, r2_round,
                spec.n_groups, seed,
            )
            .unwrap();
        let expected: Vec<usize> = (0..spec.n_clients)
            .filter(|&i| {
                let id = partition.clients[i].client_id;
                drift.client_phase(id, r1_round, seed) != drift.client_phase(id, r2_round, seed)
            })
            .collect();
        assert_eq!(
            r2.recomputed, expected,
            "recompute set != drifted set (rounds {r1_round}->{r2_round})"
        );
        for i in 0..spec.n_clients {
            if !expected.contains(&i) {
                let a = r1.summaries.row(i);
                let b = r2.summaries.row(i);
                let same = a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "non-drifted row {i} not byte-identical");
            }
        }
    });
}

#[test]
fn cached_device_secs_match_cold_for_random_schedules() {
    // The simulated device accounting must be identical whether a row came
    // from the cache or from a recompute.
    check(4, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let fleet = FleetModel::default().sample_fleet(spec.n_clients);
        let engine = Engine::without_artifacts().unwrap();
        let jl = JlSummary::new(&spec);
        let drift = DriftSchedule::at(vec![g.usize_in(1, 6)], g.f64_in(0.2, 0.9));
        let seed = 2000 + g.case as u64;

        let mut cached = FleetRefresher::new(RefreshOptions {
            backend: ClusterBackend::Lloyd,
            ..Default::default()
        });
        for round in [0, g.usize_in(1, 10)] {
            let warm = cached
                .refresh(
                    &engine, &jl, &partition, &generator, &fleet, &drift, round,
                    spec.n_groups, seed,
                )
                .unwrap();
            let cold = FleetRefresher::new(RefreshOptions {
                backend: ClusterBackend::Lloyd,
                use_cache: false,
                ..Default::default()
            })
            .refresh(
                &engine, &jl, &partition, &generator, &fleet, &drift, round,
                spec.n_groups, seed,
            )
            .unwrap();
            for (i, (a, b)) in warm.device_secs.iter().zip(&cold.device_secs).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "device_secs client {i} round {round}");
            }
            assert_eq!(warm.clusters, cold.clusters, "clusters at round {round}");
        }
    });
}

#[test]
fn streaming_coreset_equals_materialized_for_random_k() {
    // Fuzz the fused coreset builder: for random clients, phases, coreset
    // sizes, and rng seeds, build_coreset_streaming must reproduce
    // build_coreset(client_dataset) bit for bit — images, labels, padding.
    check(20, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let part = &partition.clients[g.usize_in(0, partition.clients.len() - 1)];
        let phase = g.usize_in(0, 2) as u64;
        let k = g.usize_in(1, 40);
        let seed = g.case as u64 + 4000;
        let ds = generator.client_dataset(part, phase);
        let a = coreset::build_coreset(&ds, spec.classes, k, &mut Rng::new(seed));
        let b = coreset::build_coreset_streaming(
            &generator,
            part,
            phase,
            spec.classes,
            k,
            &mut Rng::new(seed),
        );
        assert_eq!(a.real, b.real);
        assert_eq!(a.labels, b.labels);
        for (x, y) in a.images.iter().zip(&b.images) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    });
}

#[test]
fn fused_refresh_equals_materialized_for_random_schedules() {
    // Crate-boundary fuzz of the tentpole oracle: random drift schedules,
    // rounds, seeds, and thread counts — the fused refresh must be bitwise
    // identical to the materialized one (summaries, clusters, device secs).
    check(5, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let fleet = FleetModel::default().sample_fleet(spec.n_clients);
        let engine = Engine::without_artifacts().unwrap();
        let jl = JlSummary::new(&spec);
        let drift = DriftSchedule::at(vec![g.usize_in(1, 6)], g.f64_in(0.2, 1.0));
        let round = g.usize_in(0, 10);
        let seed = 5000 + g.case as u64;
        let threads = [1, 4, 8][g.usize_in(0, 2)];
        let use_cache = g.case % 2 == 0;
        let run = |fused: bool| {
            FleetRefresher::new(RefreshOptions {
                backend: ClusterBackend::Lloyd,
                use_cache,
                threads,
                fused,
                ..Default::default()
            })
            .refresh(
                &engine, &jl, &partition, &generator, &fleet, &drift, round,
                spec.n_groups, seed,
            )
            .unwrap()
        };
        let a = run(true);
        let b = run(false);
        for (x, y) in a.summaries.data().iter().zip(b.summaries.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.clusters, b.clusters);
        for (x, y) in a.device_secs.iter().zip(&b.device_secs) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    });
}

#[test]
fn generator_rejects_nothing_and_stays_in_range() {
    check(8, |g| {
        let spec = DatasetSpec::tiny();
        let partition = Partition::build(&spec);
        let generator = Generator::new(&spec);
        let part = &partition.clients[g.usize_in(0, partition.clients.len() - 1)];
        let phase = g.usize_in(0, 3) as u64;
        let ds = generator.client_dataset(part, phase);
        assert_eq!(ds.images.len(), ds.n * spec.flat_dim());
        assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(ds.labels.iter().all(|&l| (l as usize) < spec.classes));
    });
}

// ---------------------------------------------------------------------------
// Event-journal fuzz: random transition histories must round-trip through
// JSONL bitwise, truncation at any byte must recover exactly the complete
// record prefix, and a simulator recovered at EVERY journal prefix must
// converge to the same event digest as the uninterrupted run.

/// A random but legal transition history: `rounds` full rounds with random
/// payloads (including empty selections and non-aggregated rounds).
fn random_journal(g: &mut feddde::util::proptest::Gen, rounds: usize) -> EventJournal {
    let n_clients = g.usize_in(5, 60);
    let header = JournalHeader {
        kind: if g.bool() { "sim".into() } else { "train".into() },
        seed: g.case as u64,
        rounds,
        n_clients,
        per_round: g.usize_in(1, n_clients),
        policy: ["random", "cluster", "oort"][g.usize_in(0, 2)].into(),
        scenario: if g.bool() { "sync_baseline".into() } else { String::new() },
    };
    let mut m = CoordinatorMachine::new(header);
    for round in 0..rounds {
        m.apply(Transition::RoundStarted { round }).unwrap();
        let available = g.usize_in(0, n_clients);
        m.apply(Transition::FleetRendezvoused { round, available }).unwrap();
        let k = g.usize_in(0, n_clients.min(8));
        let selected: Vec<usize> = (0..k).map(|i| i * 2 + 1).collect();
        m.apply(Transition::ClientsSelected { round, selected: selected.clone() }).unwrap();
        // Partition the selection into the four terminal buckets (the
        // `failed` bucket is often empty, exercising its elided encoding).
        let cut1 = g.usize_in(0, selected.len());
        let cut2 = g.usize_in(cut1, selected.len());
        let cut3 = g.usize_in(cut2, selected.len());
        m.apply(Transition::TrainingEnded {
            round,
            completed: selected[..cut1].to_vec(),
            dropped: selected[cut1..cut2].to_vec(),
            timed_out: selected[cut2..cut3].to_vec(),
            failed: selected[cut3..].to_vec(),
        })
        .unwrap();
        m.apply(Transition::RoundAggregated {
            round,
            aggregated: cut1 > 0,
            degraded: cut1 > 0 && g.bool(),
        })
        .unwrap();
    }
    m.into_journal()
}

#[test]
fn journal_roundtrip_is_bitwise_for_random_histories() {
    check(15, |g| {
        let j = random_journal(g, g.usize_in(1, 6));
        let text = j.to_jsonl();
        let parsed = EventJournal::parse(&text).unwrap();
        assert_eq!(parsed.to_jsonl(), text, "serialize → parse → serialize moved bytes");
        assert_eq!(parsed.digest(), j.digest());
        assert_eq!(parsed.records(), j.records());
    });
}

#[test]
fn truncated_journal_recovers_to_the_last_complete_transition() {
    check(10, |g| {
        let j = random_journal(g, g.usize_in(1, 4));
        let text = j.to_jsonl();
        let header_len = text.find('\n').unwrap() + 1;
        // Random byte cuts, always including a mid-line tear.
        for _ in 0..12 {
            let cut = g.usize_in(header_len, text.len());
            let parsed = EventJournal::parse(&text[..cut])
                .unwrap_or_else(|e| panic!("cut at {cut}: {e:#}"));
            let complete = text[..cut].lines().skip(1).filter(|l| l.ends_with('}')).count();
            assert_eq!(parsed.len(), complete, "cut at byte {cut}");
            assert_eq!(parsed.records(), &j.records()[..complete]);
        }
    });
}

#[test]
fn sim_recovered_at_every_journal_prefix_converges_to_the_same_digest() {
    // The recover-at-every-prefix sweep: truncate the journal after each
    // record in turn, recover a simulator from it, finish the run, and
    // require the exact digests of the uninterrupted run — crash timing can
    // never fork history.
    let cfg = SimConfig {
        n_clients: 30,
        rounds: 4,
        per_round: 6,
        refresh_every: 2,
        seed: 41,
        ..Default::default()
    };
    let sc = Scenario::by_name("sync_baseline").unwrap();
    let (rep, journal) = Simulator::new(cfg.clone(), sc.clone())
        .unwrap()
        .run_journaled()
        .unwrap();
    let want_journal = journal.digest();
    let want_events = rep.event_digest();
    for keep in 0..=journal.len() {
        let truncated = journal.truncated(keep);
        let resumed = Simulator::recover(cfg.clone(), sc.clone(), &truncated)
            .unwrap_or_else(|e| panic!("recover at prefix {keep}: {e:#}"));
        let (rep2, j2) = resumed
            .run_journaled()
            .unwrap_or_else(|e| panic!("resume from prefix {keep}: {e:#}"));
        assert_eq!(j2.digest(), want_journal, "journal digest diverged at prefix {keep}");
        assert_eq!(rep2.event_digest(), want_events, "event digest diverged at prefix {keep}");
    }
}

// ---------------------------------------------------------------------------
// Fleet-simulator fuzz: random scenarios must never violate the event-queue
// contract (pops monotone in time, nothing fires before its round began) or
// leak a client out of the completed/dropped/timed-out partition.

#[test]
fn sim_random_scenarios_preserve_event_and_client_invariants() {
    check(8, |g| {
        let mut sc = Scenario::baseline("fuzz", "randomized scenario");
        sc.aggregation = if g.bool() {
            Aggregation::Sync
        } else {
            Aggregation::Quorum { frac: g.f64_in(0.2, 0.9) }
        };
        sc.availability = match g.usize_in(0, 2) {
            0 => AvailabilityModel::Base,
            1 => AvailabilityModel::Diurnal {
                period: g.usize_in(2, 10),
                amplitude: g.f64_in(0.1, 0.8),
            },
            _ => AvailabilityModel::FlashCrowd {
                join_round: g.usize_in(0, 2),
                leave_round: g.usize_in(3, 6),
                frac: g.f64_in(0.1, 0.6),
            },
        };
        sc.straggler = if g.bool() {
            StragglerModel::Off
        } else {
            StragglerModel::HeavyTail {
                frac: g.f64_in(0.05, 0.4),
                mult_mu: g.f64_in(0.5, 2.5),
                mult_sigma: g.f64_in(0.2, 1.0),
            }
        };
        sc.dropout_rate = g.f64_in(0.0, 0.5);
        sc.over_select = g.f64_in(1.0, 2.0);
        sc.deadline_pct = g.f64_in(50.0, 100.0);
        if g.bool() {
            sc.drift = DriftSchedule::at(vec![g.usize_in(1, 3)], g.f64_in(0.2, 1.0));
        }
        let cfg = SimConfig {
            n_clients: g.usize_in(10, 50),
            rounds: g.usize_in(2, 5),
            per_round: g.usize_in(2, 8),
            refresh_every: g.usize_in(0, 3),
            policy: STRATEGY_NAMES[g.usize_in(0, STRATEGY_NAMES.len() - 1)].into(),
            seed: 100 + g.case as u64,
            ..Default::default()
        };
        let rounds = cfg.rounds;
        let rep = Simulator::new(cfg, sc).unwrap().run().unwrap();

        // Every selected client terminates in exactly one of the three
        // states, rounds are well-formed, coverage is monotone.
        assert_eq!(rep.rounds.len(), rounds);
        let mut last_end = 0.0f64;
        let mut last_cov = 0.0f64;
        for r in &rep.rounds {
            assert_eq!(
                r.completed + r.dropped + r.timed_out + r.failed,
                r.selected,
                "round {}: {} + {} + {} + {} != {}",
                r.round,
                r.completed,
                r.dropped,
                r.timed_out,
                r.failed,
                r.selected
            );
            assert!(r.t_start >= last_end - 1e-12 && r.t_end >= r.t_start);
            assert!(r.coverage >= last_cov && (0.0..=1.0).contains(&r.coverage));
            let parts = r.refresh_secs
                + r.selection_secs
                + r.compute_secs
                + r.upload_secs
                + r.wait_secs;
            assert!(
                (parts - r.round_secs).abs() <= 1e-9 * r.round_secs.max(1.0),
                "round {} breakdown mismatch",
                r.round
            );
            last_end = r.t_end;
            last_cov = r.coverage;
        }

        // Event stream: pops are globally monotone in time, ties broken so
        // ids never regress at equal times, and no event fires before its
        // round started.
        let mut last_t = 0.0f64;
        let mut last_id_at_t = None::<u64>;
        for e in &rep.events {
            assert!(e.time >= last_t, "event time ran backwards");
            if e.time == last_t {
                if let Some(prev) = last_id_at_t {
                    assert!(e.id > prev, "tie-break violated at t={}", e.time);
                }
            }
            let r = &rep.rounds[e.round];
            assert!(
                e.time >= r.t_start,
                "round {} event at {} before round start {}",
                e.round,
                e.time,
                r.t_start
            );
            last_id_at_t = Some(e.id);
            last_t = e.time;
        }
    });
}

// ---------------------------------------------------------------------------
// Non-finite-loss fuzz: a client can report a NaN or ±inf training loss (a
// diverged local model). The ranking comparators used to be
// `partial_cmp().unwrap()`, which panics on the first NaN; these pin the
// fixed behavior — never panic, stay valid and deterministic, and rank the
// NaN-bearing client last instead of letting it jump the queue.

#[test]
fn selection_strategies_survive_non_finite_losses() {
    check(10, |g| {
        let n = g.usize_in(6, 50);
        let fleet = FleetModel::default().sample_fleet(n);
        let clusters: Vec<usize> = (0..n).map(|_| g.usize_in(0, 3)).collect();
        let losses: Vec<Option<f64>> = (0..n)
            .map(|_| match g.usize_in(0, 5) {
                0 => Some(f64::NAN),
                1 => Some(f64::INFINITY),
                2 => Some(f64::NEG_INFINITY),
                3 => None,
                _ => Some(g.f64_in(0.05, 3.0)),
            })
            .collect();
        let views: Vec<ClientView> = (0..n)
            .map(|i| ClientView {
                client_id: i,
                cluster: clusters[i],
                device: &fleet[i],
                available: true,
                quarantined: false,
                n_samples: 20 + i,
                last_loss: losses[i],
                step_host_secs: 0.01,
                upload_bytes: 1_000_000,
            })
            .collect();
        let k = g.usize_in(1, n);
        for name in STRATEGY_NAMES {
            let run = || {
                let mut p = selection::Builder::new(name).build().unwrap();
                p.select(&views, 0, k, &mut Rng::new(g.case as u64))
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{name}: same seed, different selection");
            assert!(validate_selection(&a, &views, k), "{name} invalid: {a:?}");
            assert!(!a.is_empty(), "{name} selected nothing from an all-available fleet");
        }
    });
}

#[test]
fn oort_ranks_nan_utility_last() {
    // Every client tried (empty exploration pool), one NaN loss: the
    // NaN-utility client must never displace a finite-utility one.
    check(10, |g| {
        let n = g.usize_in(5, 30);
        let fleet = FleetModel::default().sample_fleet(n);
        let nan_client = g.usize_in(0, n - 1);
        let losses: Vec<f64> =
            (0..n).map(|i| if i == nan_client { f64::NAN } else { g.f64_in(0.1, 3.0) }).collect();
        let views: Vec<ClientView> = (0..n)
            .map(|i| ClientView {
                client_id: i,
                cluster: 0,
                device: &fleet[i],
                available: true,
                quarantined: false,
                n_samples: 100,
                last_loss: Some(losses[i]),
                step_host_secs: 0.01,
                upload_bytes: 1_000_000,
            })
            .collect();
        let k = g.usize_in(1, n - 1);
        let mut p = selection::Builder::new("oort").build().unwrap();
        let sel = p.select(&views, 0, k, &mut Rng::new(7));
        assert_eq!(sel.len(), k);
        assert!(
            !sel.contains(&nan_client),
            "NaN-loss client {nan_client} selected at k={k} < n={n}: {sel:?}"
        );
    });
}

#[test]
fn cluster_ranks_nan_duration_last() {
    // One device with a NaN step cost (NaN expected round duration) in a
    // single cluster: with exploration off, the fastest-first ranking must
    // leave it for last, never pick it while finite-cost devices remain.
    check(10, |g| {
        let n = g.usize_in(4, 30);
        let fleet = FleetModel::default().sample_fleet(n);
        let nan_client = g.usize_in(0, n - 1);
        let views: Vec<ClientView> = (0..n)
            .map(|i| ClientView {
                client_id: i,
                cluster: 0,
                device: &fleet[i],
                available: true,
                quarantined: false,
                n_samples: 50,
                last_loss: Some(1.0),
                step_host_secs: if i == nan_client { f64::NAN } else { 0.01 },
                upload_bytes: 1_000_000,
            })
            .collect();
        let k = g.usize_in(1, n - 1);
        let mut p = ClusterSelection { explore_eps: 0.0, local_steps: 4 };
        let sel = p.select(&views, 0, k, &mut Rng::new(9));
        assert_eq!(sel.len(), k);
        assert!(
            !sel.contains(&nan_client),
            "NaN-duration device {nan_client} jumped the queue: {sel:?}"
        );
    });
}

// ---------------------------------------------------------------------------
// Fault-injection fuzz: random fault plans must never leak a client out of
// the four-way completed/dropped/timed-out/failed partition, must stay
// bitwise deterministic across refresh thread counts AND across a
// crash/recover/resume at a random journal prefix, and a plan whose fault
// rates are all zero must be indistinguishable — event stream and journal
// bytes — from the inert default, whatever its resilience knobs say.

/// A random but legal fault plan: rates drawn across their whole ranges,
/// resilience knobs (retries, backoff, quarantine) randomized independently.
fn random_fault_plan(g: &mut feddde::util::proptest::Gen) -> FaultPlan {
    let mut f = FaultPlan::inert();
    f.upload_fail_rate = g.f64_in(0.0, 0.5);
    f.heartbeat_loss_rate = g.f64_in(0.0, 0.2);
    f.corrupt_rate = g.f64_in(0.0, 0.4);
    if g.bool() {
        f.outage_frac = g.f64_in(0.1, 0.5);
        f.outage_start = g.usize_in(0, 3);
        f.outage_rounds = g.usize_in(1, 3);
    }
    f.max_retries = g.usize_in(0, 4) as u32;
    f.quarantine_threshold = g.usize_in(0, 4) as u32;
    f.probation_rounds = g.usize_in(0, 3);
    f.backoff_base_secs = g.f64_in(0.1, 5.0);
    f.backoff_cap_secs = f.backoff_base_secs * g.f64_in(1.0, 20.0);
    f.backoff_jitter = g.f64_in(0.0, 0.5);
    f.stale_discount = g.f64_in(0.05, 1.0);
    f.validate().expect("generated plan must be legal");
    f
}

#[test]
fn sim_random_fault_plans_preserve_the_client_partition() {
    check(6, |g| {
        let mut sc = Scenario::baseline("fault_fuzz", "randomized fault plan");
        sc.fault = random_fault_plan(g);
        sc.dropout_rate = g.f64_in(0.0, 0.3);
        sc.over_select = g.f64_in(1.0, 1.5);
        if g.bool() {
            sc.aggregation = Aggregation::Quorum { frac: g.f64_in(0.3, 0.9) };
        }
        let cfg = SimConfig {
            n_clients: g.usize_in(10, 40),
            rounds: g.usize_in(2, 5),
            per_round: g.usize_in(2, 8),
            refresh_every: 2,
            seed: 9000 + g.case as u64,
            ..Default::default()
        };
        let rounds = cfg.rounds;
        let rep = Simulator::new(cfg, sc).unwrap().run().unwrap();
        assert_eq!(rep.rounds.len(), rounds, "faulty run lost rounds");
        for r in &rep.rounds {
            assert_eq!(
                r.completed + r.dropped + r.timed_out + r.failed,
                r.selected,
                "round {}: {} + {} + {} + {} != {}",
                r.round,
                r.completed,
                r.dropped,
                r.timed_out,
                r.failed,
                r.selected
            );
        }
    });
}

#[test]
fn sim_random_fault_plans_are_bitwise_deterministic_and_replayable() {
    check(4, |g| {
        let mut sc = Scenario::baseline("fault_det", "randomized fault determinism");
        sc.fault = random_fault_plan(g);
        sc.dropout_rate = 0.1;
        sc.over_select = 1.3;
        let cfg = |threads: usize| SimConfig {
            n_clients: 30,
            rounds: 3,
            per_round: 6,
            refresh_every: 2,
            threads,
            seed: 9100 + g.case as u64,
            ..Default::default()
        };
        let (rep, journal) =
            Simulator::new(cfg(1), sc.clone()).unwrap().run_journaled().unwrap();
        for threads in [4usize, 8] {
            let (r2, j2) =
                Simulator::new(cfg(threads), sc.clone()).unwrap().run_journaled().unwrap();
            assert_eq!(r2.event_digest(), rep.event_digest(), "events forked at threads={threads}");
            assert_eq!(j2.digest(), journal.digest(), "journal forked at threads={threads}");
        }
        // Crash at a random journal prefix, recover, resume: retries,
        // backoff timing, and quarantine state must all re-derive bitwise.
        let keep = g.usize_in(0, journal.len());
        let resumed = Simulator::recover(cfg(1), sc.clone(), &journal.truncated(keep))
            .unwrap_or_else(|e| panic!("recover at prefix {keep}: {e:#}"));
        let (r3, j3) = resumed
            .run_journaled()
            .unwrap_or_else(|e| panic!("resume from prefix {keep}: {e:#}"));
        assert_eq!(j3.digest(), journal.digest(), "journal digest diverged at prefix {keep}");
        assert_eq!(r3.event_digest(), rep.event_digest(), "event digest diverged at prefix {keep}");
    });
}

#[test]
fn zeroed_fault_rates_leave_the_event_stream_bitwise_untouched() {
    // The zero-fault identity, fuzzed over the resilience knobs: a plan with
    // every fault RATE at zero is inert no matter how the retry/backoff/
    // quarantine knobs are set, and must reproduce the default plan's event
    // stream and journal byte for byte (straggler_cut keeps dropouts and
    // deadline kills in play so the inert path is genuinely exercised).
    check(5, |g| {
        let cfg = SimConfig {
            n_clients: 25,
            rounds: 3,
            per_round: 5,
            refresh_every: 2,
            seed: 9200 + g.case as u64,
            ..Default::default()
        };
        let base = Scenario::by_name("straggler_cut").unwrap();
        let (want_rep, want_j) =
            Simulator::new(cfg.clone(), base.clone()).unwrap().run_journaled().unwrap();
        let mut f = FaultPlan::inert();
        f.max_retries = g.usize_in(0, 9) as u32;
        f.quarantine_threshold = g.usize_in(0, 9) as u32;
        f.probation_rounds = g.usize_in(0, 9);
        f.backoff_base_secs = g.f64_in(0.01, 10.0);
        f.backoff_cap_secs = f.backoff_base_secs * g.f64_in(1.0, 10.0);
        f.backoff_jitter = g.f64_in(0.0, 1.0);
        f.stale_discount = g.f64_in(0.05, 1.0);
        assert!(f.is_inert(), "zero-rate plan classified as active: {f:?}");
        let mut sc = base;
        sc.fault = f;
        let (rep, j) = Simulator::new(cfg, sc).unwrap().run_journaled().unwrap();
        assert_eq!(rep.event_digest(), want_rep.event_digest(), "event stream moved");
        assert_eq!(j.to_jsonl(), want_j.to_jsonl(), "journal bytes moved");
    });
}

// ---------------------------------------------------------------------------
// Sharded-fleet fuzz: coordinator shard count and lazy arrival sampling are
// execution strategies, never semantics. Random small fleets (N <= 200),
// scenarios, and seeds — the lazy run must equal the eager run bitwise for
// every cohort-invariant policy, and any shard count must reproduce the
// flat coordinator's stream and journal byte for byte.

#[test]
fn lazy_arrivals_equal_eager_for_random_small_fleets() {
    check(6, |g| {
        let scenario =
            ["sync_baseline", "straggler_cut", "diurnal", "flash_crowd", "heavy_tail"]
                [g.usize_in(0, 4)];
        // Cohort-invariant policies only: `cluster` refreshes over the
        // arrived cohort and `round_robin` cursors over the full fleet, so
        // their lazy runs legitimately diverge.
        let policy = ["random", "oort", "powd"][g.usize_in(0, 2)];
        let cfg = |lazy: bool| SimConfig {
            n_clients: g.usize_in(10, 200),
            rounds: g.usize_in(2, 5),
            per_round: g.usize_in(2, 10),
            refresh_every: g.usize_in(0, 3),
            policy: policy.into(),
            lazy_arrivals: lazy,
            seed: 9300 + g.case as u64,
            ..Default::default()
        };
        let sc = Scenario::by_name(scenario).unwrap();
        let (eager, ej) =
            Simulator::new(cfg(false), sc.clone()).unwrap().run_journaled().unwrap();
        let (lazy, lj) = Simulator::new(cfg(true), sc).unwrap().run_journaled().unwrap();
        assert_eq!(
            lazy.event_digest(),
            eager.event_digest(),
            "{policy}/{scenario}: lazy arrivals forked the event stream"
        );
        assert_eq!(lazy.events_jsonl(), eager.events_jsonl(), "{policy}/{scenario}: stream bytes");
        assert_eq!(lj.to_jsonl(), ej.to_jsonl(), "{policy}/{scenario}: journal bytes");
        for (a, b) in eager.rounds.iter().zip(&lazy.rounds) {
            assert_eq!(a.to_json(), b.to_json(), "{policy}/{scenario}: round {} report", a.round);
        }
    });
}

// ---------------------------------------------------------------------------
// Telemetry fuzz: whatever the scenario shape or fault plan, a traced run's
// span tree must be structurally sound — unique ids, parents before
// children, children contained in the parent's window, per-parent child
// durations summing to at most the parent's — and its root `round` spans
// must reproduce the report's round times bit for bit.

#[test]
fn traced_random_scenarios_produce_well_nested_span_trees() {
    use feddde::obs::profile::{check_well_nested, parse_trace, round_totals};
    check(6, |g| {
        let mut sc = Scenario::baseline("trace_fuzz", "randomized traced scenario");
        sc.aggregation = if g.bool() {
            Aggregation::Sync
        } else {
            Aggregation::Quorum { frac: g.f64_in(0.2, 0.9) }
        };
        sc.availability = match g.usize_in(0, 2) {
            0 => AvailabilityModel::Base,
            1 => AvailabilityModel::Diurnal {
                period: g.usize_in(2, 10),
                amplitude: g.f64_in(0.1, 0.8),
            },
            _ => AvailabilityModel::FlashCrowd {
                join_round: g.usize_in(0, 2),
                leave_round: g.usize_in(3, 6),
                frac: g.f64_in(0.1, 0.6),
            },
        };
        sc.straggler = if g.bool() {
            StragglerModel::Off
        } else {
            StragglerModel::HeavyTail {
                frac: g.f64_in(0.05, 0.4),
                mult_mu: g.f64_in(0.5, 2.5),
                mult_sigma: g.f64_in(0.2, 1.0),
            }
        };
        sc.dropout_rate = g.f64_in(0.0, 0.5);
        sc.over_select = g.f64_in(1.0, 2.0);
        sc.deadline_pct = g.f64_in(50.0, 100.0);
        if g.bool() {
            sc.drift = DriftSchedule::at(vec![g.usize_in(1, 3)], g.f64_in(0.2, 1.0));
        }
        let cfg = SimConfig {
            n_clients: g.usize_in(10, 50),
            rounds: g.usize_in(2, 5),
            per_round: g.usize_in(2, 8),
            refresh_every: g.usize_in(0, 3),
            policy: STRATEGY_NAMES[g.usize_in(0, STRATEGY_NAMES.len() - 1)].into(),
            shards: [1, 1, 4][g.usize_in(0, 2)],
            seed: 9500 + g.case as u64,
            trace: "trace.jsonl".into(),
            ..Default::default()
        };
        let run = Simulator::new(cfg, sc).unwrap().run_traced().unwrap();
        let spans = parse_trace(&run.tracer.to_jsonl()).unwrap();
        check_well_nested(&spans, 1e-9).unwrap_or_else(|e| panic!("case {}: {e}", g.case));
        let totals = round_totals(&spans);
        assert_eq!(totals.len(), run.report.rounds.len(), "one root span per round");
        for ((round, total), row) in totals.iter().zip(&run.report.rounds) {
            assert_eq!(*round, row.round as u64);
            assert_eq!(
                total.to_bits(),
                row.round_secs.to_bits(),
                "round {round}: root span != reported round_secs"
            );
        }
    });
}

#[test]
fn traced_random_fault_plans_produce_well_nested_span_trees() {
    use feddde::obs::profile::{check_well_nested, parse_trace};
    check(5, |g| {
        let mut sc = Scenario::baseline("trace_fault_fuzz", "randomized traced fault plan");
        sc.fault = random_fault_plan(g);
        sc.dropout_rate = g.f64_in(0.0, 0.3);
        sc.over_select = g.f64_in(1.0, 1.5);
        let cfg = SimConfig {
            n_clients: g.usize_in(10, 40),
            rounds: g.usize_in(2, 5),
            per_round: g.usize_in(2, 8),
            refresh_every: 2,
            seed: 9600 + g.case as u64,
            trace: "trace.jsonl".into(),
            ..Default::default()
        };
        let rounds = cfg.rounds;
        let run = Simulator::new(cfg, sc).unwrap().run_traced().unwrap();
        let spans = parse_trace(&run.tracer.to_jsonl()).unwrap();
        check_well_nested(&spans, 1e-9).unwrap_or_else(|e| panic!("case {}: {e}", g.case));
        // Registry reconciliation under faults: the per-round counters must
        // sum to the report's totals whatever the fault draws did.
        assert_eq!(run.registry.counter("rounds_total"), rounds as u64);
        let t = run.report.totals();
        assert_eq!(run.registry.counter("retries_total"), t.retries, "retries_total");
        assert_eq!(
            run.registry.counter("completed_total"),
            t.completed as u64,
            "completed_total"
        );
        assert_eq!(
            run.registry.counter("summary_rejects_total"),
            t.summary_rejects,
            "summary_rejects_total"
        );
    });
}

#[test]
fn shard_counts_reproduce_the_flat_stream_for_random_fleets() {
    check(5, |g| {
        let scenario =
            ["sync_baseline", "straggler_cut", "drift_burst"][g.usize_in(0, 2)];
        let cfg = |shards: usize| SimConfig {
            n_clients: g.usize_in(10, 120),
            rounds: g.usize_in(2, 4),
            per_round: g.usize_in(2, 8),
            refresh_every: g.usize_in(1, 2),
            shards,
            seed: 9400 + g.case as u64,
            ..Default::default()
        };
        let sc = Scenario::by_name(scenario).unwrap();
        let (flat, fj) =
            Simulator::new(cfg(1), sc.clone()).unwrap().run_journaled().unwrap();
        let shards = [2, 4, 7, 16][g.usize_in(0, 3)];
        let (sharded, sj) =
            Simulator::new(cfg(shards), sc).unwrap().run_journaled().unwrap();
        assert_eq!(
            sharded.event_digest(),
            flat.event_digest(),
            "{scenario}: shards={shards} forked the event stream"
        );
        assert_eq!(sj.to_jsonl(), fj.to_jsonl(), "{scenario}: shards={shards} moved the journal");
    });
}
